//! Discrete-event cluster simulator.
//!
//! Regenerates the paper's evaluation (Figs 3, 10, 11, 13, 14, 15, 19,
//! 20) by simulating continuous-batching inference servers with the
//! calibrated [`gpu::GpuModel`] latencies, fed by [`workload`]
//! generators, optionally routed by a [`crate::scheduler::Policy`].
//! [`front::SimFront`] additionally exposes a single instance behind the
//! streaming [`crate::server::ServingFront`] API, so lifecycle-level
//! code runs unchanged against simulator or real engine.

pub mod front;
pub mod gpu;
pub mod instance;
pub mod workload;

use std::cmp::Ordering;
use std::collections::BinaryHeap;

pub use front::SimFront;
pub use gpu::GpuModel;
pub use instance::{AdapterCache, IterOutcome, ServingMode, SimInstance, SimReq};
pub use workload::{AlpacaLengths, MafTrace, WorkloadRequest};

use crate::scheduler::{Policy, SchedRequest, ServerStats};

/// Final per-request metrics (the paper's three headline metrics §7.1).
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub id: u64,
    pub adapter: u64,
    pub rank: usize,
    pub server: usize,
    pub arrival: f64,
    /// Time to first token (s).
    pub ttft: f64,
    /// Average time per output token (s) — total latency / tokens, the
    /// perceived generation speed.
    pub time_per_token: f64,
    /// End-to-end request latency (s).
    pub latency: f64,
    /// Cold-start seconds this request was exposed to.
    pub cold_start: f64,
    pub output_len: usize,
}

impl RequestMetrics {
    fn from_sim(sr: &SimReq, server: usize) -> RequestMetrics {
        let arrival = sr.req.arrival;
        let first = sr.first_token.expect("unfinished request");
        let finish = sr.finish.expect("unfinished request");
        let latency = finish - arrival;
        RequestMetrics {
            id: sr.req.id,
            adapter: sr.req.adapter,
            rank: sr.req.rank,
            server,
            arrival,
            ttft: first - arrival,
            time_per_token: latency / sr.req.output_len.max(1) as f64,
            latency,
            cold_start: sr.cold_start,
            output_len: sr.req.output_len,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    Arrival(usize),
    IterEnd(usize),
}

#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap by (time, seq) through BinaryHeap's max semantics.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// The simulation: a set of instances plus a routing policy.
pub struct Simulation {
    pub instances: Vec<SimInstance>,
}

/// Summary outputs of one run.
pub struct SimOutput {
    pub requests: Vec<RequestMetrics>,
    /// (is_prefill, duration) per iteration per instance.
    pub iterations: Vec<Vec<instance::IterRecord>>,
}

impl SimOutput {
    /// SLO attainment: fraction of requests with time-per-token ≤ `slo`.
    pub fn slo_attainment(&self, slo: f64) -> f64 {
        if self.requests.is_empty() {
            return 1.0;
        }
        let ok = self
            .requests
            .iter()
            .filter(|r| r.time_per_token <= slo)
            .count();
        ok as f64 / self.requests.len() as f64
    }

    /// Extract a metric column.
    pub fn column(&self, metric: &str) -> Vec<f64> {
        self.requests
            .iter()
            .map(|r| match metric {
                "ttft" => r.ttft,
                "tpt" => r.time_per_token,
                "latency" => r.latency,
                "cold" => r.cold_start,
                "cold_frac" => {
                    if r.latency > 0.0 {
                        r.cold_start / r.latency
                    } else {
                        0.0
                    }
                }
                other => panic!("unknown metric {other}"),
            })
            .collect()
    }
}

impl Simulation {
    /// New simulation over the given instances.
    pub fn new(instances: Vec<SimInstance>) -> Simulation {
        Simulation { instances }
    }

    /// Run `requests` (sorted by arrival) through the cluster, routing
    /// each arrival with `policy`. Returns per-request metrics.
    ///
    /// Single-instance experiments pass any policy; with one instance
    /// every request routes there.
    pub fn run(
        &mut self,
        requests: &[WorkloadRequest],
        policy: &mut dyn Policy,
    ) -> SimOutput {
        let mut heap: BinaryHeap<Event> = BinaryHeap::new();
        let mut seq = 0u64;
        for (i, r) in requests.iter().enumerate() {
            heap.push(Event {
                time: r.arrival,
                seq,
                kind: EventKind::Arrival(i),
            });
            seq += 1;
        }
        let mut routed_server: Vec<usize> = vec![usize::MAX; requests.len()];
        // Reused stats buffers: refilled in place per arrival instead of
        // reallocating (hot at 60 instances × 40k arrivals; §Perf).
        // Simulated instances host any adapter and model no bounded KV
        // pool, so the eligibility fields stay at their defaults
        // (`AdapterSet::Any`, unbounded headroom).
        let mut stats: Vec<ServerStats> =
            self.instances.iter().map(|_| ServerStats::default()).collect();

        while let Some(ev) = heap.pop() {
            match ev.kind {
                EventKind::Arrival(i) => {
                    let r = &requests[i];
                    for (inst, s) in self.instances.iter().zip(stats.iter_mut()) {
                        s.running_ranks.clear();
                        s.running_ranks
                            .extend(inst.running.iter().map(|r| r.req.rank));
                        s.queued_ranks.clear();
                        s.queued_ranks.extend(inst.queue.iter().map(|r| r.req.rank));
                    }
                    let sreq = SchedRequest {
                        id: r.id,
                        adapter: r.adapter,
                        rank: r.rank,
                        prompt_len: r.prompt_len,
                    };
                    let target = policy.pick(&sreq, &stats).expect("no eligible server");
                    routed_server[i] = target;
                    let inst = &mut self.instances[target];
                    inst.enqueue(r.clone());
                    if !inst.busy {
                        let dur = inst.start_iteration(ev.time);
                        heap.push(Event {
                            time: ev.time + dur,
                            seq,
                            kind: EventKind::IterEnd(target),
                        });
                        seq += 1;
                    }
                }
                EventKind::IterEnd(target) => {
                    let inst = &mut self.instances[target];
                    inst.finish_iteration(ev.time);
                    if inst.has_work() {
                        let dur = inst.start_iteration(ev.time);
                        heap.push(Event {
                            time: ev.time + dur,
                            seq,
                            kind: EventKind::IterEnd(target),
                        });
                        seq += 1;
                    }
                }
            }
        }

        // Collect metrics.
        let mut out = Vec::new();
        for inst in &self.instances {
            assert!(
                inst.queue.is_empty() && inst.running.is_empty(),
                "instance {} finished with work pending",
                inst.id
            );
            for sr in &inst.done {
                out.push(RequestMetrics::from_sim(sr, inst.id));
            }
        }
        out.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        SimOutput {
            iterations: self
                .instances
                .iter()
                .map(|i| i.iters.clone())
                .collect(),
            requests: out,
        }
    }
}

/// A trivial always-server-0 policy for single-instance experiments.
pub struct SingleServer;

impl Policy for SingleServer {
    fn pick(&mut self, _req: &SchedRequest, stats: &[ServerStats]) -> Option<usize> {
        if stats.is_empty() {
            None
        } else {
            Some(0)
        }
    }
    fn name(&self) -> &'static str {
        "single"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;

    fn one_instance(mode: ServingMode) -> Simulation {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        Simulation::new(vec![SimInstance::new(0, model, mode, 32, 8, 512)])
    }

    #[test]
    fn all_requests_complete_and_metrics_sane() {
        let reqs = workload::synthetic(1, 3.0, 64, 30.0);
        let n = reqs.len();
        let mut sim = one_instance(ServingMode::CaraServe);
        let out = sim.run(&reqs, &mut SingleServer);
        assert_eq!(out.requests.len(), n);
        for r in &out.requests {
            assert!(r.ttft > 0.0, "ttft {}", r.ttft);
            assert!(r.latency >= r.ttft);
            assert!(r.time_per_token > 0.0);
            assert!(r.cold_start >= 0.0);
        }
    }

    #[test]
    fn cached_beats_ondemand_beats_nothing() {
        // The paper's core ordering: Cached ≤ CaraServe < OnDemand on TTFT.
        let reqs = workload::synthetic(2, 6.0, 64, 60.0);
        let mean = |mode| {
            let mut sim = one_instance(mode);
            let out = sim.run(&reqs, &mut SingleServer);
            crate::util::stats::mean(&out.column("ttft"))
        };
        let cached = mean(ServingMode::Cached);
        let cara = mean(ServingMode::CaraServe);
        let ondmd = mean(ServingMode::OnDemand);
        assert!(cached <= cara * 1.05, "cached={cached} cara={cara}");
        assert!(cara < ondmd, "cara={cara} ondmd={ondmd}");
    }

    #[test]
    fn higher_load_increases_cold_start_fraction() {
        // Fig 3-Left: cold-start share grows with RPS.
        let frac = |rps| {
            let trace = MafTrace::new(7, 512, 1.0, &[64]);
            let reqs = trace.generate(8, rps, 60.0);
            let mut sim = one_instance(ServingMode::OnDemand);
            let out = sim.run(&reqs, &mut SingleServer);
            crate::util::stats::mean(&out.column("cold_frac"))
        };
        let lo = frac(2.0);
        let hi = frac(6.0);
        assert!(hi > lo, "lo={lo} hi={hi}");
    }

    #[test]
    fn multi_instance_routing_spreads_load() {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let instances: Vec<SimInstance> = (0..4)
            .map(|i| {
                SimInstance::new(
                    i,
                    model.clone(),
                    ServingMode::Cached,
                    32,
                    8,
                    usize::MAX,
                )
            })
            .collect();
        let mut sim = Simulation::new(instances);
        let reqs = workload::synthetic(3, 20.0, 32, 30.0);
        let mut policy = crate::scheduler::baselines::MostIdle;
        let out = sim.run(&reqs, &mut policy);
        let mut per_server = [0usize; 4];
        for r in &out.requests {
            per_server[r.server] += 1;
        }
        assert!(per_server.iter().all(|&c| c > 0), "{per_server:?}");
    }

    #[test]
    fn slo_attainment_bounds() {
        let reqs = workload::synthetic(4, 3.0, 64, 20.0);
        let mut sim = one_instance(ServingMode::Cached);
        let out = sim.run(&reqs, &mut SingleServer);
        assert_eq!(out.slo_attainment(f64::INFINITY), 1.0);
        assert_eq!(out.slo_attainment(0.0), 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let reqs = workload::synthetic(5, 5.0, 64, 20.0);
        let run = || {
            let mut sim = one_instance(ServingMode::CaraServe);
            sim.run(&reqs, &mut SingleServer)
                .column("latency")
                .iter()
                .sum::<f64>()
        };
        assert_eq!(run(), run());
    }
}
