//! Analytical GPU latency model (roofline-style), calibrated to the
//! paper's A10/A100 measurements.
//!
//! The paper's latency behaviour decomposes cleanly:
//!
//! - **decode** iterations are memory-bandwidth bound: every iteration
//!   streams all weights + the batch's KV cache once;
//! - **prefill** iterations are compute bound (large GEMMs);
//! - **LoRA kernel overhead** is membw bound (>70% membw in the paper's
//!   Nsight profile): BGMV streams `|S|·max_rank` padded adapter rows,
//!   MBGMV streams `Σ rank` — the linear models of Fig 9 fall out of the
//!   byte counts;
//! - **adapter loading** is PCIe transfer + a fixed driver/alloc floor
//!   (Fig 3-Right);
//! - **CPU LoRA** prefill runs at a per-core token rate with near-linear
//!   multi-core scaling (Fig 18).

use crate::config::GpuSpec;
use crate::model::{LlamaConfig, LoraSpec};
use crate::perfmodel::KernelKind;

/// Latency model for one server's GPU(s).
#[derive(Debug, Clone)]
pub struct GpuModel {
    pub cfg: LlamaConfig,
    pub gpu: GpuSpec,
    /// Tensor-parallel degree.
    pub tp: usize,
    /// Efficiency of TP scaling (NCCL overhead): 1 GPU → 1.0.
    pub tp_eff: f64,
    /// Fixed per-iteration launch/framework overhead (seconds).
    pub iter_overhead: f64,
}

impl GpuModel {
    /// Standard model for a (model, gpu, tp) triple.
    pub fn new(cfg: LlamaConfig, gpu: GpuSpec, tp: usize) -> GpuModel {
        GpuModel {
            cfg,
            gpu,
            tp,
            tp_eff: if tp > 1 { 0.85 } else { 1.0 },
            // LightLLM-style frameworks spend a few ms per iteration on
            // batching, sampling, and kernel launches.
            iter_overhead: 4e-3,
        }
    }

    /// Aggregate effective memory bandwidth across TP shards.
    fn agg_mem_bw(&self) -> f64 {
        self.gpu.eff_mem_bw() * self.tp as f64 * self.tp_eff
    }

    /// Aggregate effective compute across TP shards.
    fn agg_flops(&self) -> f64 {
        self.gpu.eff_flops() * self.tp as f64 * self.tp_eff
    }

    /// One decode iteration for a batch with the given per-request
    /// context lengths (tokens attended). Membw-bound: stream weights
    /// once + each request's KV.
    pub fn decode_iter(&self, ctx_lens: &[usize]) -> f64 {
        if ctx_lens.is_empty() {
            return 0.0;
        }
        let kv_bytes: f64 = ctx_lens
            .iter()
            .map(|&c| c as f64 * self.cfg.kv_bytes_per_token())
            .sum();
        let bytes = self.cfg.weight_bytes() + kv_bytes;
        self.iter_overhead + bytes / self.agg_mem_bw()
    }

    /// A prefill pass over `total_tokens` prompt tokens (compute bound).
    pub fn prefill(&self, total_tokens: usize) -> f64 {
        if total_tokens == 0 {
            return 0.0;
        }
        let flops = self.cfg.fwd_flops(total_tokens as f64, total_tokens as f64);
        self.iter_overhead + flops / self.agg_flops()
    }

    /// Per-iteration GPU LoRA kernel overhead for a batch with the given
    /// adapter ranks (decode: one token per request).
    pub fn lora_decode_overhead(&self, kernel: KernelKind, ranks: &[usize]) -> f64 {
        if ranks.is_empty() {
            return 0.0;
        }
        // Bytes streamed per token per rank unit: A row + B row per layer
        // per target, fp16.
        let per_rank_bytes = 4.0 // A column + B row, 2 bytes each
            * self.cfg.hidden as f64
            * self.cfg.layers as f64
            * 3.0; // Q, K, V
        let feature = kernel.feature(ranks);
        // Kernel launch floor per iteration (32 layers × 3 launches).
        let launch = 2e-6 * self.cfg.layers as f64 * 3.0;
        launch + feature * per_rank_bytes / self.agg_mem_bw()
    }

    /// Cold-start: load one adapter host→device (Fig 3-Right).
    pub fn adapter_load(&self, spec: &LoraSpec) -> f64 {
        self.gpu.h2d_time(spec.weight_bytes(&self.cfg))
    }

    /// CPU-LoRA prefill token rate for one host core (tokens/s) at the
    /// given rank: xAB is 4·H·r FLOPs per token per layer per target.
    pub fn cpu_core_token_rate(&self, rank: usize) -> f64 {
        // One vectorized host core sustains ~32 GFLOP/s on this GEMM
        // shape (calibrated so that Fig 18-Left's single-core curve and
        // §7.2's 22% TTFT overhead over CACHED both hold).
        let core_flops = 32e9;
        let flops_per_token =
            4.0 * self.cfg.hidden as f64 * rank as f64 * self.cfg.layers as f64 * 3.0;
        core_flops / flops_per_token
    }

    /// CPU-LoRA prefill time for `tokens` across `cores` with the
    /// paper's multi-core scaling (near-linear: 1.7×/8 over the
    /// PyTorch-native baseline, ~0.92 parallel efficiency per doubling).
    pub fn cpu_prefill(&self, tokens: usize, rank: usize, cores: usize) -> f64 {
        if tokens == 0 {
            return 0.0;
        }
        let cores = cores.max(1) as f64;
        let rate = self.cpu_core_token_rate(rank) * cores.powf(0.95);
        tokens as f64 / rate
    }

    /// CaraServe's effective prefill cost for one cold request (§4.1
    /// "Mitigating GPU cold-start", Fig 1/7).
    ///
    /// During the load window the **base model keeps running on the
    /// GPU**; only the lightweight xAB runs on host cores, layer-
    /// synchronized through shared memory. Prefill therefore completes in
    /// `max(gpu_prefill, cpu_lora_time)` plus the sub-ms sync overhead —
    /// nearly independent of the adapter load time (whatever loading
    /// remains after prefill is hidden behind the first decode
    /// iterations, where CPU LoRA trivially covers 1 token/request).
    ///
    /// Returns (total_prefill_time, residual_coldstart_exposed).
    pub fn overlapped_prefill(
        &self,
        prompt: usize,
        rank: usize,
        cores: usize,
        _load_time: f64,
    ) -> (f64, f64) {
        let gpu_time = self.prefill(prompt);
        // Time for the host cores to push the prompt through xAB.
        let cpu_time = self.cpu_prefill(prompt, rank, cores);
        // Sync overhead of the layer-wise CPU/GPU exchange: sub-ms total
        // with shared memory + the fused async memcpy+signal operator
        // (Figs 16/17).
        let sync = 0.8e-3;
        let total = gpu_time.max(cpu_time) + sync;
        let residual = (total - gpu_time).max(0.0);
        (total, residual)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a10_7b() -> GpuModel {
        GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1)
    }

    #[test]
    fn decode_iter_matches_paper_scale() {
        // Paper Fig 4/5: decode iterations for Llama2-7B/A10 with tens of
        // requests land in the ~30–40 ms band.
        let m = a10_7b();
        let d = m.decode_iter(&vec![256; 24]);
        assert!((25e-3..55e-3).contains(&d), "decode={d}");
    }

    #[test]
    fn decode_scales_with_batch_kv() {
        let m = a10_7b();
        assert!(m.decode_iter(&vec![512; 16]) > m.decode_iter(&vec![128; 16]));
        assert!(m.decode_iter(&vec![256; 32]) > m.decode_iter(&vec![256; 8]));
        assert_eq!(m.decode_iter(&[]), 0.0);
    }

    #[test]
    fn prefill_matches_paper_scale() {
        // A 128-token prompt on 7B/A10: ~tens of ms.
        let m = a10_7b();
        let p = m.prefill(128);
        assert!((10e-3..120e-3).contains(&p), "prefill={p}");
    }

    #[test]
    fn adapter_load_matches_fig3_right() {
        // Fig 3-Right: rank 8..128 loads take ~few..tens of ms on A10.
        let m = a10_7b();
        let cfg = LlamaConfig::llama2_7b();
        let t8 = m.adapter_load(&LoraSpec::standard(1, 8, &cfg.name));
        let t64 = m.adapter_load(&LoraSpec::standard(1, 64, &cfg.name));
        let t128 = m.adapter_load(&LoraSpec::standard(1, 128, &cfg.name));
        assert!((5e-3..12e-3).contains(&t8), "t8={t8}");
        assert!((15e-3..30e-3).contains(&t64), "t64={t64}");
        assert!(t128 > t64 && t64 > t8);
    }

    #[test]
    fn bgmv_overhead_tracks_max_rank() {
        let m = a10_7b();
        let homo = m.lora_decode_overhead(KernelKind::Bgmv, &vec![32; 24]);
        let mut with64 = vec![32; 24];
        with64.push(64);
        let bumped = m.lora_decode_overhead(KernelKind::Bgmv, &with64);
        assert!(bumped > homo * 1.7, "homo={homo} bumped={bumped}");
        // MBGMV only grows by the added rank.
        let m_homo = m.lora_decode_overhead(KernelKind::Mbgmv, &vec![32; 24]);
        let m_bumped = m.lora_decode_overhead(KernelKind::Mbgmv, &with64);
        assert!(m_bumped < m_homo * 1.2);
    }

    #[test]
    fn overlapped_prefill_hides_most_of_the_load() {
        let m = a10_7b();
        let cfg = LlamaConfig::llama2_7b();
        let spec = LoraSpec::standard(1, 64, &cfg.name);
        let load = m.adapter_load(&spec);
        let prompt = 128;
        // With 8 cores a 128-token prompt is CPU-bound: no worse than
        // load-then-prefill (this is why §4.2 allocates ⌈L/c⌉ cores).
        let (total8, residual8) = m.overlapped_prefill(prompt, 64, 8, load);
        let naive = load + m.prefill(prompt);
        assert!(total8 <= naive * 1.01, "total8={total8} naive={naive}");
        assert!(residual8 <= load * 1.6, "residual8={residual8} load={load}");
        // With the profiling-guided core allotment the reduction is
        // large (§4.2 headline: 57.9% prefill latency reduction).
        let (total, _) = m.overlapped_prefill(prompt, 64, 32, load);
        let reduction = 1.0 - total / naive;
        assert!(
            (0.2..0.95).contains(&reduction),
            "reduction={reduction} total={total} naive={naive}"
        );
        // With ample cores the residual exposure is sub-5ms (sync + CPU
        // slowdown only), regardless of adapter size.
        let (_, residual_many) = m.overlapped_prefill(prompt, 64, 32, load);
        assert!(residual_many < 5e-3, "residual_many={residual_many}");
    }

    #[test]
    fn tp_speeds_up_decode() {
        let cfg = LlamaConfig::llama2_13b();
        let m1 = GpuModel::new(cfg.clone(), GpuSpec::a10(), 1);
        let m2 = GpuModel::new(cfg, GpuSpec::a10(), 2);
        assert!(m2.decode_iter(&vec![256; 8]) < m1.decode_iter(&vec![256; 8]));
    }

    #[test]
    fn cpu_rate_is_plausible() {
        // Fig 18-Left: one core handles ~10s of tokens within a prefill
        // window for 7B-scale adapters.
        let m = a10_7b();
        let rate = m.cpu_core_token_rate(64);
        assert!((50.0..5000.0).contains(&rate), "rate={rate}");
    }
}
