//! Workload generation (paper §7.1): synthetic Poisson traffic, the
//! scaled MAF production trace, and Alpaca-like prompt/output lengths.

use crate::util::rng::{Rng, Zipf};

/// One generated inference request.
#[derive(Debug, Clone)]
pub struct WorkloadRequest {
    pub id: u64,
    /// Arrival time (seconds from experiment start).
    pub arrival: f64,
    /// LoRA adapter id.
    pub adapter: u64,
    /// Adapter rank.
    pub rank: usize,
    /// Prompt length (tokens).
    pub prompt_len: usize,
    /// Output length (tokens to generate).
    pub output_len: usize,
}

/// Alpaca-dataset-like length sampler (paper: "we set each request's
/// input prompt and output length according to the Alpaca dataset").
/// Alpaca instructions are short (median ≈ 15–25 tokens) with a heavy
/// tail; outputs average ≈ 60 tokens with a long tail.
#[derive(Debug, Clone)]
pub struct AlpacaLengths {
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    pub output_mu: f64,
    pub output_sigma: f64,
    pub max_prompt: usize,
    pub max_output: usize,
}

impl Default for AlpacaLengths {
    fn default() -> Self {
        AlpacaLengths {
            // lognormal(3.0, 0.8): median ~20, mean ~28.
            prompt_mu: 3.0,
            prompt_sigma: 0.8,
            // lognormal(3.9, 0.8): median ~49, mean ~68.
            output_mu: 3.9,
            output_sigma: 0.8,
            max_prompt: 512,
            max_output: 512,
        }
    }
}

impl AlpacaLengths {
    /// Sample (prompt_len, output_len).
    pub fn sample(&self, rng: &mut Rng) -> (usize, usize) {
        let p = rng.lognormal(self.prompt_mu, self.prompt_sigma).round() as usize;
        let o = rng.lognormal(self.output_mu, self.output_sigma).round() as usize;
        (p.clamp(4, self.max_prompt), o.clamp(1, self.max_output))
    }
}

/// Synthetic workload (§7.2): Poisson arrivals at `rps`, every request
/// targeting a *distinct* adapter of fixed `rank` ("each request targets
/// a distinct adapter and hence undergoes the adapter loading phase").
pub fn synthetic(
    seed: u64,
    rps: f64,
    rank: usize,
    duration_s: f64,
) -> Vec<WorkloadRequest> {
    let mut rng = Rng::new(seed);
    let lengths = AlpacaLengths::default();
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    loop {
        t += rng.exp(rps);
        if t > duration_s {
            break;
        }
        let (prompt_len, output_len) = lengths.sample(&mut rng);
        out.push(WorkloadRequest {
            id,
            arrival: t,
            adapter: id, // distinct adapter per request
            rank,
            prompt_len,
            output_len,
        });
        id += 1;
    }
    out
}

/// The MAF-like trace (paper Fig 12): `n_adapters` functions whose
/// invocation probabilities follow a skewed (Zipf) popularity, arrivals
/// aggregated as Poisson at `rps`.
#[derive(Debug, Clone)]
pub struct MafTrace {
    /// Invocation probability per adapter, sorted descending.
    pub popularity: Vec<f64>,
    /// Rank per adapter.
    pub ranks: Vec<usize>,
}

impl MafTrace {
    /// Build a skewed trace: popularity Zipf(s), ranks drawn from
    /// `rank_choices` uniformly (heterogeneous serving, §7.5).
    pub fn new(seed: u64, n_adapters: usize, skew: f64, rank_choices: &[usize]) -> MafTrace {
        let zipf = Zipf::new(n_adapters, skew);
        let mut rng = Rng::new(seed);
        let popularity = (0..n_adapters).map(|k| zipf.pmf(k)).collect();
        let ranks = (0..n_adapters)
            .map(|_| *rng.choose(rank_choices))
            .collect();
        MafTrace { popularity, ranks }
    }

    /// Number of adapters (functions).
    pub fn len(&self) -> usize {
        self.popularity.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.popularity.is_empty()
    }

    /// Generate requests: Poisson aggregate at `rps` for `duration_s`,
    /// each invocation drawn from the popularity PMF.
    pub fn generate(&self, seed: u64, rps: f64, duration_s: f64) -> Vec<WorkloadRequest> {
        let mut rng = Rng::new(seed);
        let lengths = AlpacaLengths::default();
        let mut out = Vec::new();
        let mut t = 0.0;
        let mut id = 0u64;
        loop {
            t += rng.exp(rps);
            if t > duration_s {
                break;
            }
            let adapter = rng.discrete(&self.popularity) as u64;
            let (prompt_len, output_len) = lengths.sample(&mut rng);
            out.push(WorkloadRequest {
                id,
                arrival: t,
                adapter,
                rank: self.ranks[adapter as usize],
                prompt_len,
                output_len,
            });
            id += 1;
        }
        out
    }

    /// The paper's per-group aggregate RPS scaling (§7.2): 128 adapters →
    /// 1.5 rps, 256 → 3.6, 512 → 7.7.
    pub fn scaled_rps(n_adapters: usize) -> f64 {
        // Linear-ish in adapter count per the paper's reported triples.
        match n_adapters {
            0..=128 => 1.5 * n_adapters as f64 / 128.0,
            129..=256 => 1.5 + (3.6 - 1.5) * (n_adapters - 128) as f64 / 128.0,
            _ => 3.6 + (7.7 - 3.6) * (n_adapters - 256) as f64 / 256.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_rate_and_distinct_adapters() {
        let reqs = synthetic(1, 9.0, 64, 60.0);
        // ~540 requests expected.
        assert!((430..650).contains(&reqs.len()), "n={}", reqs.len());
        let mut adapters: Vec<u64> = reqs.iter().map(|r| r.adapter).collect();
        adapters.sort_unstable();
        adapters.dedup();
        assert_eq!(adapters.len(), reqs.len(), "adapters must be distinct");
        assert!(reqs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(reqs.iter().all(|r| r.rank == 64));
    }

    #[test]
    fn alpaca_lengths_in_range() {
        let mut rng = Rng::new(5);
        let l = AlpacaLengths::default();
        let mut prompt_sum = 0usize;
        let n = 10_000;
        for _ in 0..n {
            let (p, o) = l.sample(&mut rng);
            assert!((4..=512).contains(&p));
            assert!((1..=512).contains(&o));
            prompt_sum += p;
        }
        let mean = prompt_sum as f64 / n as f64;
        assert!((15.0..45.0).contains(&mean), "mean prompt {mean}");
    }

    #[test]
    fn maf_popularity_is_skewed_and_normalized() {
        let trace = MafTrace::new(1, 512, 1.0, &[64]);
        let total: f64 = trace.popularity.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
        // Fig 12 shape: head ≫ tail.
        assert!(trace.popularity[0] > trace.popularity[511] * 50.0);
        let head: f64 = trace.popularity[..32].iter().sum();
        assert!(head > 0.4, "head mass {head}");
    }

    #[test]
    fn maf_generation_matches_popularity() {
        let trace = MafTrace::new(2, 64, 1.0, &[8, 16, 32, 64]);
        let reqs = trace.generate(3, 50.0, 200.0);
        assert!(reqs.len() > 5_000);
        let mut counts = vec![0usize; 64];
        for r in &reqs {
            counts[r.adapter as usize] += 1;
            assert_eq!(r.rank, trace.ranks[r.adapter as usize]);
        }
        // Most popular adapter invoked far more than median one.
        assert!(counts[0] > counts[32] * 3, "{} vs {}", counts[0], counts[32]);
    }

    #[test]
    fn scaled_rps_matches_paper_points() {
        assert!((MafTrace::scaled_rps(128) - 1.5).abs() < 1e-9);
        assert!((MafTrace::scaled_rps(256) - 3.6).abs() < 1e-9);
        assert!((MafTrace::scaled_rps(512) - 7.7).abs() < 1e-9);
    }
}
