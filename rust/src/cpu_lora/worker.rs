//! The CPU-LoRA worker pool (paper §4.2).
//!
//! Each worker emulates one of the paper's isolated, core-pinned LoRA
//! processes: it owns one shared-memory [`SlotChannel`] and loops
//! `recv x-slice → compute xAB → send result`. Job metadata (adapter id,
//! target, token count) travels in a small fixed header at the front of
//! the shm payload — nothing is serialized.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;

use crate::ipc::shm::{slot_channels, ShmRegion, SlotChannel};
use crate::kernels::gemm::lora_apply;
use crate::kernels::AdapterWeights;
use crate::model::TargetMatrix;

/// Header floats prepended to each request payload:
/// `[adapter_lo, adapter_hi, target_idx, n_tok, hidden]`.
///
/// The adapter id travels as two 24-bit words (each exactly
/// representable in f32): a single f32 word silently rounds ids above
/// 2^24, making the worker compute against the wrong adapter. Ids up to
/// 2^48 − 1 round-trip exactly; [`WorkerPool::submit`] asserts the
/// bound.
pub const HEADER_F32S: usize = 5;

/// Adapter ids must fit the two 24-bit shm header words.
pub const MAX_ADAPTER_ID: u64 = (1 << 48) - 1;

fn target_idx(t: TargetMatrix) -> usize {
    match t {
        TargetMatrix::Q => 0,
        TargetMatrix::K => 1,
        TargetMatrix::V => 2,
        TargetMatrix::O => 3,
    }
}

/// Host-memory adapter weight table shared by the base process and all
/// workers (the paper's "local LoRA repository" compute view): adapter id
/// → per-target (A, B) weights.
#[derive(Default)]
pub struct AdapterTable {
    inner: RwLock<HashMap<u64, Arc<[AdapterWeights; 4]>>>,
}

impl AdapterTable {
    /// Empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Install an explicit Q/K/V/O weight stack for adapter `id` — the
    /// path real weights take when the engine sources them from the
    /// content-addressed artifact store.
    pub fn install(&self, id: u64, stack: [AdapterWeights; 4]) {
        self.inner.write().unwrap().insert(id, Arc::new(stack));
    }

    /// Install synthetic weights for adapter `id` with `rank` at `hidden`.
    /// Targets Q/K/V/O all get weights (O unused in the standard config).
    /// Delegates to [`crate::artifacts::synthetic_stack`] so the seeded
    /// stacks the artifact pipeline publishes are bitwise-identical to
    /// what this installs.
    pub fn install_synthetic(&self, id: u64, hidden: usize, rank: usize) {
        self.install(id, crate::artifacts::synthetic_stack(id, hidden, rank));
    }

    /// Fetch an adapter's weights.
    pub fn get(&self, id: u64) -> Option<Arc<[AdapterWeights; 4]>> {
        self.inner.read().unwrap().get(&id).cloned()
    }

    /// Drop an adapter's weights (runtime uninstall). In-flight holders
    /// of the `Arc` keep computing against the old weights until they
    /// release it; new lookups miss. Returns true if it was installed.
    pub fn remove(&self, id: u64) -> bool {
        self.inner.write().unwrap().remove(&id).is_some()
    }

    /// Number of installed adapters.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A pool of CPU-LoRA workers, one per (simulated) core.
pub struct WorkerPool {
    /// Keep the shm region alive for the workers' lifetime.
    _region: Arc<ShmRegion>,
    slots: Vec<Arc<SlotChannel>>,
    handles: Vec<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    table: Arc<AdapterTable>,
    /// Requests submitted per slot; compared against the slot's response
    /// doorbell so `Drop` can drain in-flight jobs before poisoning.
    submitted: Vec<AtomicU32>,
}

impl WorkerPool {
    /// Spawn `n_workers` workers, each with a slot holding up to
    /// `max_tokens`×`hidden` activation floats.
    pub fn spawn(
        n_workers: usize,
        hidden: usize,
        max_tokens: usize,
        table: Arc<AdapterTable>,
    ) -> Result<WorkerPool, crate::ipc::shm::ShmError> {
        let capacity = HEADER_F32S + max_tokens * hidden;
        let (region, raw_slots) = slot_channels(n_workers, capacity)?;
        let region = Arc::new(region);
        let slots: Vec<Arc<SlotChannel>> = raw_slots.into_iter().map(Arc::new).collect();
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for slot in &slots {
            let slot = slot.clone();
            let stop = stop.clone();
            let table = table.clone();
            let region = region.clone();
            handles.push(std::thread::spawn(move || {
                let _keep = region;
                worker_loop(&slot, &stop, &table);
            }));
        }
        let submitted = (0..slots.len()).map(|_| AtomicU32::new(0)).collect();
        Ok(WorkerPool {
            _region: region,
            slots,
            handles,
            stop,
            table,
            submitted,
        })
    }

    /// Number of workers.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True if no workers.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The shared adapter table.
    pub fn table(&self) -> &Arc<AdapterTable> {
        &self.table
    }

    /// Submit `x` (n_tok×hidden) for adapter/target to worker `w`.
    /// Returns the doorbell token to pass to [`Self::collect`].
    pub fn submit(
        &self,
        w: usize,
        adapter_id: u64,
        target: TargetMatrix,
        n_tok: usize,
        hidden: usize,
        x: &[f32],
    ) -> u32 {
        assert_eq!(x.len(), n_tok * hidden);
        assert!(
            adapter_id <= MAX_ADAPTER_ID,
            "adapter id {adapter_id} exceeds the shm header encoding (2^48 − 1)"
        );
        let mut payload = Vec::with_capacity(HEADER_F32S + x.len());
        payload.push((adapter_id & 0xFF_FFFF) as f32);
        payload.push((adapter_id >> 24) as f32);
        payload.push(target_idx(target) as f32);
        payload.push(n_tok as f32);
        payload.push(hidden as f32);
        payload.extend_from_slice(x);
        self.submitted[w].fetch_add(1, Ordering::AcqRel);
        self.slots[w].send_request(&payload)
    }

    /// Block until worker `w` responds; the result (n_tok×hidden xAB) is
    /// appended into `out`.
    pub fn collect(&self, w: usize, token: u32, out: &mut Vec<f32>) {
        self.slots[w].recv_response(token, out);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Drain in-flight jobs first: a poison request racing a worker's
        // in-progress job used to interleave with its response publication
        // (and, under the old shared-`len` header, clobber its length).
        // A slot is quiescent once its response doorbell has caught up
        // with everything submitted. Bounded wait so a leaked (never-
        // collected) token cannot hang teardown.
        let deadline = std::time::Instant::now() + std::time::Duration::from_millis(200);
        for (w, slot) in self.slots.iter().enumerate() {
            let want = self.submitted[w].load(Ordering::Acquire);
            while slot.response_seq() < want && std::time::Instant::now() < deadline {
                std::thread::yield_now();
            }
        }
        self.stop.store(true, Ordering::Release);
        // Wake each worker with a poison request.
        for slot in &self.slots {
            slot.send_request(&[f32::NAN, 0.0, 0.0, 0.0, 0.0]);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(slot: &SlotChannel, stop: &AtomicBool, table: &AdapterTable) {
    // Bootstrap from 0, not request_seq(): the region is freshly zeroed,
    // and a request may already have been submitted (ringing the bell)
    // before this thread first observes the slot — reading the live
    // sequence here would swallow that request and deadlock the caller.
    let mut seen = 0u32;
    let mut buf: Vec<f32> = Vec::new();
    let mut y: Vec<f32> = Vec::new();
    let mut scratch: Vec<f32> = Vec::new();
    loop {
        seen = slot.recv_request(seen, &mut buf);
        if stop.load(Ordering::Acquire) {
            return;
        }
        if buf.len() < HEADER_F32S || buf[0].is_nan() {
            // Only the NaN shutdown poison goes unanswered (its sender is
            // tearing the pool down). Any other short/corrupt message
            // still gets a best-effort empty response so a producer
            // blocked in collect() never hangs.
            if !buf.first().is_some_and(|v| v.is_nan()) {
                slot.send_response(&[]);
            }
            continue;
        }
        // Validate the header before trusting it: the payload travels
        // over shared memory, and a truncated or corrupted message used
        // to panic this thread on an out-of-bounds slice — permanently
        // deadlocking every future `collect()` on the slot. Malformed
        // jobs get a best-effort zero response instead (the base process
        // treats it as "no adaptation") and the worker stays alive.
        let header_ok = buf[..HEADER_F32S].iter().all(|v| v.is_finite() && *v >= 0.0);
        let n_tok = buf[3].max(0.0) as usize;
        let hidden = buf[4].max(0.0) as usize;
        let expect = n_tok.checked_mul(hidden);
        let payload_ok = header_ok
            && expect.is_some_and(|e| {
                e <= slot.capacity().saturating_sub(HEADER_F32S)
                    && buf.len() >= HEADER_F32S + e
            });
        if !payload_ok {
            let e = expect
                .unwrap_or(0)
                .min(slot.capacity().saturating_sub(HEADER_F32S));
            y.clear();
            y.resize(e, 0.0);
            slot.send_response(&y);
            continue;
        }
        let adapter_id = (buf[0] as u64) | ((buf[1] as u64) << 24);
        let t_idx = buf[2] as usize;
        let x = &buf[HEADER_F32S..HEADER_F32S + n_tok * hidden];
        match table.get(adapter_id) {
            // The adapter's shapes must match the header's `hidden`, or
            // lora_apply's shape asserts would panic the worker (same
            // permanent-deadlock failure as a truncated payload).
            Some(weights) if weights[t_idx.min(3)].h1 == hidden
                && weights[t_idx.min(3)].h2 == hidden =>
            {
                let ad = &weights[t_idx.min(3)];
                y.clear();
                y.resize(n_tok * hidden, 0.0);
                scratch.clear();
                scratch.resize(n_tok * ad.rank, 0.0);
                lora_apply(
                    n_tok, hidden, hidden, ad.rank, x, &ad.a, &ad.b, &mut y,
                    &mut scratch,
                );
                slot.send_response(&y);
            }
            // Unknown adapter or shape mismatch: respond with zeros so
            // the base process never deadlocks; it treats this as "no
            // adaptation".
            _ => {
                y.clear();
                y.resize(n_tok * hidden, 0.0);
                slot.send_response(&y);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::lora_apply;

    #[test]
    fn worker_computes_xab() {
        let hidden = 32;
        let rank = 4;
        let table = Arc::new(AdapterTable::new());
        table.install_synthetic(7, hidden, rank);
        let pool = WorkerPool::spawn(2, hidden, 16, table.clone()).unwrap();

        let n_tok = 5;
        let x: Vec<f32> = (0..n_tok * hidden).map(|i| (i % 13) as f32 * 0.1).collect();
        let token = pool.submit(0, 7, TargetMatrix::Q, n_tok, hidden, &x);
        let mut got = Vec::new();
        pool.collect(0, token, &mut got);

        // Reference.
        let weights = table.get(7).unwrap();
        let ad = &weights[0];
        let mut want = vec![0.0f32; n_tok * hidden];
        let mut scratch = vec![0.0f32; n_tok * rank];
        lora_apply(
            n_tok, hidden, hidden, rank, &x, &ad.a, &ad.b, &mut want, &mut scratch,
        );
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn unknown_adapter_returns_zeros() {
        let table = Arc::new(AdapterTable::new());
        let pool = WorkerPool::spawn(1, 8, 4, table).unwrap();
        let token = pool.submit(0, 999, TargetMatrix::K, 2, 8, &[1.0; 16]);
        let mut got = Vec::new();
        pool.collect(0, token, &mut got);
        assert_eq!(got, vec![0.0; 16]);
    }

    #[test]
    fn pool_shuts_down_cleanly() {
        let table = Arc::new(AdapterTable::new());
        let pool = WorkerPool::spawn(4, 8, 4, table).unwrap();
        assert_eq!(pool.len(), 4);
        drop(pool); // must not hang
    }

    #[test]
    fn malformed_header_gets_zero_response_and_worker_survives() {
        let hidden = 8;
        let table = Arc::new(AdapterTable::new());
        table.install_synthetic(1, hidden, 2);
        let pool = WorkerPool::spawn(1, hidden, 16, table).unwrap();

        // Shorter than the header itself (non-poison): empty response,
        // not a silent skip that would hang collect() forever.
        let resp_seen = pool.slots[0].send_request(&[1.0, 2.0]);
        let mut short = Vec::new();
        pool.slots[0].recv_response(resp_seen, &mut short);
        assert!(short.is_empty());

        // Truncated payload: header claims 4×8 = 32 floats, sends none.
        let resp_seen =
            pool.slots[0].send_request(&[1.0, 0.0, 0.0, 4.0, hidden as f32]);
        let mut got = Vec::new();
        pool.slots[0].recv_response(resp_seen, &mut got);
        assert_eq!(got, vec![0.0; 4 * hidden], "zeros for truncated payload");

        // Absurd token count (would overflow the slot): still answered.
        let resp_seen =
            pool.slots[0].send_request(&[1.0, 0.0, 0.0, 1e9, hidden as f32]);
        pool.slots[0].recv_response(resp_seen, &mut got);
        assert!(got.iter().all(|&v| v == 0.0));

        // Non-finite header field: answered, not panicked.
        let resp_seen =
            pool.slots[0].send_request(&[1.0, 0.0, f32::INFINITY, 1.0, hidden as f32]);
        pool.slots[0].recv_response(resp_seen, &mut got);
        assert!(got.iter().all(|&v| v == 0.0));

        // Corrupted `hidden` word (valid lengths, wrong adapter shape):
        // zeros, not a shape-assert panic inside lora_apply.
        let mut bad = vec![1.0, 0.0, 0.0, 2.0, (hidden / 2) as f32];
        bad.extend(vec![1.0f32; hidden]); // 2 × (hidden/2) payload floats
        let resp_seen = pool.slots[0].send_request(&bad);
        pool.slots[0].recv_response(resp_seen, &mut got);
        assert_eq!(got, vec![0.0; hidden]);

        // The worker is still alive and serves a well-formed job.
        let x = vec![1.0f32; hidden];
        let token = pool.submit(0, 1, TargetMatrix::Q, 1, hidden, &x);
        pool.collect(0, token, &mut got);
        assert_eq!(got.len(), hidden);
        assert!(got.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn adapter_ids_beyond_f32_precision_round_trip() {
        // A single-f32 id word rounds 2^24 + 1 to 2^24; the two-word
        // encoding must address the right adapter.
        let hidden = 8;
        let id = (1u64 << 24) + 1;
        let table = Arc::new(AdapterTable::new());
        table.install_synthetic(id, hidden, 2);
        table.install_synthetic(1 << 24, hidden, 2); // the collision victim
        let pool = WorkerPool::spawn(1, hidden, 8, table.clone()).unwrap();
        let x = vec![1.0f32; hidden];
        let token = pool.submit(0, id, TargetMatrix::Q, 1, hidden, &x);
        let mut got = Vec::new();
        pool.collect(0, token, &mut got);
        // Reference against the *correct* adapter's weights.
        let weights = table.get(id).unwrap();
        let ad = &weights[0];
        let mut want = vec![0.0f32; hidden];
        let mut scratch = vec![0.0f32; ad.rank];
        lora_apply(1, hidden, hidden, ad.rank, &x, &ad.a, &ad.b, &mut want, &mut scratch);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn drop_drains_in_flight_jobs() {
        let hidden = 16;
        let table = Arc::new(AdapterTable::new());
        table.install_synthetic(3, hidden, 4);
        let pool = WorkerPool::spawn(2, hidden, 64, table).unwrap();
        // Submit work and drop without collecting: Drop must wait for the
        // workers' responses before poisoning, and must not hang.
        let x = vec![0.5f32; 32 * hidden];
        let _t0 = pool.submit(0, 3, TargetMatrix::Q, 32, hidden, &x);
        let _t1 = pool.submit(1, 3, TargetMatrix::V, 32, hidden, &x);
        drop(pool); // must terminate promptly with clean joins
    }

    #[test]
    fn distinct_targets_use_distinct_weights() {
        let hidden = 16;
        let table = Arc::new(AdapterTable::new());
        table.install_synthetic(1, hidden, 2);
        let pool = WorkerPool::spawn(1, hidden, 4, table).unwrap();
        let x = vec![1.0f32; hidden];
        let t_q = pool.submit(0, 1, TargetMatrix::Q, 1, hidden, &x);
        let mut y_q = Vec::new();
        pool.collect(0, t_q, &mut y_q);
        let t_k = pool.submit(0, 1, TargetMatrix::K, 1, hidden, &x);
        let mut y_k = Vec::new();
        pool.collect(0, t_k, &mut y_k);
        assert_ne!(y_q, y_k);
    }
}
