//! The device command queue and the sync-free invocation operator
//! (paper §4.2 "Sync-free CPU LoRA invocation", Figs 8 & 16).
//!
//! CUDA executes kernels from a stream in strict FIFO order; CaraServe
//! exploits that to *fuse* the host-bound "copy x to host" and "signal
//! the CPU-LoRA workers" steps into one asynchronous device command, so
//! the submitting (base-model) thread never blocks. We model the stream
//! as a dedicated executor thread with a FIFO queue:
//!
//! - **Native** mode: the submitter enqueues the compute kernel F1 and
//!   the memcpy F2, then must *host-synchronize* (drain the queue) before
//!   it may signal the workers (F3, a host-side action), and only then
//!   enqueues the next kernel F4 — the paper's Fig 8-Top.
//! - **SyncFree** mode: F2' (copy) and F3' (signal) are a single fused
//!   command placed in the queue right after F1; F4 is enqueued
//!   immediately. FIFO ordering guarantees the copy precedes the signal —
//!   Fig 8-Bottom. The submitter never blocks.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::ipc::signal::Doorbell;

/// Invocation strategy for coordinating GPU compute with CPU LoRA.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InvokeMode {
    /// Explicit host synchronization between memcpy and signal (PyTorch-
    /// native behaviour; Fig 8-Top).
    NativeSync,
    /// Fused async memcpy+signal command (CaraServe's operator;
    /// Fig 8-Bottom).
    SyncFree,
}

enum Command {
    /// Busy-work standing in for a GPU kernel of the given duration.
    Compute(Duration),
    /// Fused copy+signal: "copy" the payload (simulated by a byte copy
    /// into the shared staging buffer) then ring the doorbell.
    FusedCopySignal {
        bytes: usize,
        bell: Arc<Doorbell>,
    },
    /// Copy only (native mode; the host signals separately after sync).
    Copy { bytes: usize },
    /// Fence: reply when every prior command has executed.
    Fence(Sender<()>),
    Stop,
}

/// A strict-FIFO device command queue with one executor thread.
pub struct DeviceQueue {
    tx: Sender<Command>,
    handle: Option<JoinHandle<()>>,
    executed: Arc<AtomicU64>,
    /// Host-side work between an explicit sync and the next kernel
    /// launch (framework/eager-mode overhead). The device idles for this
    /// long on every native-mode layer — exactly the cost the fused
    /// operator removes (Fig 8).
    host_relaunch: Duration,
}

impl DeviceQueue {
    /// Spawn the executor. `copy_bandwidth_gbps` controls how long a
    /// simulated device→host copy of N bytes occupies the queue.
    pub fn spawn(copy_bandwidth_gbps: f64) -> DeviceQueue {
        let (tx, rx) = channel::<Command>();
        let executed = Arc::new(AtomicU64::new(0));
        let counter = executed.clone();
        let handle = std::thread::spawn(move || {
            // Staging buffer standing in for pinned host memory.
            let mut staging: Vec<u8> = Vec::new();
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    Command::Compute(d) => spin_for(d),
                    Command::Copy { bytes } => {
                        simulate_copy(&mut staging, bytes, copy_bandwidth_gbps)
                    }
                    Command::FusedCopySignal { bytes, bell } => {
                        simulate_copy(&mut staging, bytes, copy_bandwidth_gbps);
                        bell.ring();
                    }
                    Command::Fence(done) => {
                        let _ = done.send(());
                        continue;
                    }
                    Command::Stop => return,
                }
                // ORDERING: progress statistic only — nothing is read
                // on the strength of this counter, so Relaxed suffices.
                counter.fetch_add(1, Ordering::Relaxed);
            }
        });
        DeviceQueue {
            tx,
            handle: Some(handle),
            executed,
            // PyTorch-eager-scale per-op host overhead after a sync.
            host_relaunch: Duration::from_micros(50),
        }
    }

    /// Override the modeled host relaunch overhead (see field docs).
    pub fn with_host_relaunch(mut self, d: Duration) -> Self {
        self.host_relaunch = d;
        self
    }

    /// Enqueue a compute kernel of duration `d` (non-blocking).
    pub fn compute(&self, d: Duration) {
        let _ = self.tx.send(Command::Compute(d));
    }

    /// Enqueue a copy of `bytes` (non-blocking).
    pub fn copy(&self, bytes: usize) {
        let _ = self.tx.send(Command::Copy { bytes });
    }

    /// Enqueue the fused copy+signal command (non-blocking).
    pub fn fused_copy_signal(&self, bytes: usize, bell: Arc<Doorbell>) {
        let _ = self.tx.send(Command::FusedCopySignal { bytes, bell });
    }

    /// Host-synchronize: block until all previously enqueued commands
    /// have executed (the explicit sync the native path requires).
    pub fn synchronize(&self) {
        let (tx, rx) = channel();
        let _ = self.tx.send(Command::Fence(tx));
        let _ = rx.recv();
    }

    /// Total commands executed (fences excluded).
    pub fn executed(&self) -> u64 {
        // ORDERING: monitoring read of the statistic above; callers
        // needing a precise count synchronize via `synchronize()`'s
        // channel rendezvous first, not via this load.
        self.executed.load(Ordering::Relaxed)
    }

    /// Run one "attention layer" invocation in the given mode and return
    /// the time the *submitter* spent blocked (the quantity Fig 16's
    /// prefill-latency difference comes from).
    ///
    /// `kernel` is the base-model kernel time per layer; `copy_bytes` the
    /// activation slice size; `bell` the workers' doorbell.
    pub fn invoke_layer(
        &self,
        mode: InvokeMode,
        kernel: Duration,
        copy_bytes: usize,
        bell: &Arc<Doorbell>,
    ) -> Duration {
        let t0 = Instant::now();
        match mode {
            InvokeMode::NativeSync => {
                self.compute(kernel); // F1
                self.copy(copy_bytes); // F2
                self.synchronize(); // explicit sync — blocks the host
                bell.ring(); // F3 from the host
                spin_for(self.host_relaunch); // framework work before F4
                self.compute(kernel); // F4 can only launch now
            }
            InvokeMode::SyncFree => {
                self.compute(kernel); // F1
                self.fused_copy_signal(copy_bytes, bell.clone()); // [F2',F3']
                self.compute(kernel); // F4 launches immediately
            }
        }
        t0.elapsed()
    }
}

impl Drop for DeviceQueue {
    fn drop(&mut self) {
        let _ = self.tx.send(Command::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn spin_for(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

fn simulate_copy(staging: &mut Vec<u8>, bytes: usize, bandwidth_gbps: f64) {
    // Do a real memcpy into the staging buffer (touches memory like a
    // pinned-host copy would), then pad to the modeled PCIe time.
    staging.resize(bytes, 0);
    let t0 = Instant::now();
    for b in staging.iter_mut() {
        *b = b.wrapping_add(1);
    }
    let target = Duration::from_secs_f64(bytes as f64 / (bandwidth_gbps * 1e9));
    if let Some(rem) = target.checked_sub(t0.elapsed()) {
        spin_for(rem);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_copy_precedes_signal() {
        let q = DeviceQueue::spawn(1000.0);
        let bell = Arc::new(Doorbell::new());
        let seen = bell.load();
        // Measure from before enqueue: on a single-core host this thread
        // may be descheduled between enqueue and wait.
        let t0 = Instant::now();
        q.compute(Duration::from_millis(5));
        q.fused_copy_signal(1024, bell.clone());
        // The bell must not ring before the 5 ms compute finishes.
        bell.wait_past(seen);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn sync_free_submitter_never_blocks() {
        let q = DeviceQueue::spawn(1000.0);
        let bell = Arc::new(Doorbell::new());
        let kernel = Duration::from_millis(2);
        let blocked =
            q.invoke_layer(InvokeMode::SyncFree, kernel, 1 << 20, &bell);
        // Submission is just three channel sends — well under a kernel.
        assert!(blocked < kernel, "submitter blocked {blocked:?}");
        q.synchronize();
    }

    #[test]
    fn native_sync_blocks_at_least_one_kernel() {
        let q = DeviceQueue::spawn(1000.0);
        let bell = Arc::new(Doorbell::new());
        let kernel = Duration::from_millis(2);
        let blocked =
            q.invoke_layer(InvokeMode::NativeSync, kernel, 1 << 20, &bell);
        assert!(blocked >= kernel, "native blocked only {blocked:?}");
        q.synchronize();
    }

    #[test]
    fn synchronize_drains() {
        let q = DeviceQueue::spawn(1000.0);
        for _ in 0..10 {
            q.compute(Duration::from_micros(100));
        }
        q.synchronize();
        assert_eq!(q.executed(), 10);
    }

    #[test]
    fn executed_counts_fused_commands() {
        let q = DeviceQueue::spawn(1000.0);
        let bell = Arc::new(Doorbell::new());
        q.fused_copy_signal(16, bell);
        q.synchronize();
        assert_eq!(q.executed(), 1);
    }
}
