//! Profiling-guided parallelization (paper §4.2, Fig 18).
//!
//! The paper profiles single-core LoRA throughput under varying token
//! counts, fixes a per-core token budget `c`, and allocates ⌈L/c⌉ cores
//! to a request of L prompt tokens. [`CoreProfile::measure`] reproduces
//! that profiling pass on the actual host using the real
//! [`crate::kernels::gemm::lora_apply`] kernel.

use std::time::Instant;

use crate::kernels::gemm::lora_apply;
use crate::kernels::AdapterWeights;

/// Result of profiling one core: throughput and the derived budget.
#[derive(Debug, Clone)]
pub struct CoreProfile {
    /// Hidden size the profile was taken at.
    pub hidden: usize,
    /// Rank the profile was taken at.
    pub rank: usize,
    /// Measured tokens/second for the xAB computation on one core.
    pub tokens_per_sec: f64,
    /// Token budget per core: the max tokens one core may be assigned
    /// so that its slice finishes within `target_ms`.
    pub tokens_per_core: usize,
    /// The latency target used to derive the budget (ms).
    pub target_ms: f64,
}

impl CoreProfile {
    /// Profile the real kernel on this host: time `xAB` over a batch of
    /// `probe_tokens` tokens, several repetitions, take the best rate.
    pub fn measure(hidden: usize, rank: usize, target_ms: f64) -> CoreProfile {
        let probe_tokens = 64usize;
        let ad = AdapterWeights::synthetic(0xC0DE, hidden, hidden, rank);
        let x = vec![0.5f32; probe_tokens * hidden];
        let mut y = vec![0.0f32; probe_tokens * hidden];
        let mut scratch = vec![0.0f32; probe_tokens * rank];
        // Warm once.
        lora_apply(
            probe_tokens,
            hidden,
            hidden,
            rank,
            &x,
            &ad.a,
            &ad.b,
            &mut y,
            &mut scratch,
        );
        let mut best_rate = 0.0f64;
        for _ in 0..5 {
            let t0 = Instant::now();
            lora_apply(
                probe_tokens,
                hidden,
                hidden,
                rank,
                &x,
                &ad.a,
                &ad.b,
                &mut y,
                &mut scratch,
            );
            let dt = t0.elapsed().as_secs_f64();
            best_rate = best_rate.max(probe_tokens as f64 / dt);
        }
        Self::from_rate(hidden, rank, best_rate, target_ms)
    }

    /// The profile [`crate::server::InferenceServer`] uses when the
    /// caller doesn't supply one: a real measurement pass on this host
    /// at the serving engine's hidden size, budgeted for a 5 ms prefill
    /// slice (the paper's per-core token budget derivation, §4.2).
    pub fn default_for(hidden: usize, rank: usize) -> CoreProfile {
        Self::measure(hidden.max(1), rank.max(1), 5.0)
    }

    /// Build a profile from an externally known rate (used by the
    /// simulator with the paper's A10-host numbers).
    pub fn from_rate(
        hidden: usize,
        rank: usize,
        tokens_per_sec: f64,
        target_ms: f64,
    ) -> CoreProfile {
        let budget = (tokens_per_sec * target_ms / 1e3).floor().max(1.0) as usize;
        CoreProfile {
            hidden,
            rank,
            tokens_per_sec,
            tokens_per_core: budget,
            target_ms,
        }
    }

    /// ⌈L/c⌉ — cores to allocate for an L-token request (§4.2), capped at
    /// `available`.
    pub fn cores_for(&self, prompt_tokens: usize, available: usize) -> usize {
        if prompt_tokens == 0 {
            return 0;
        }
        prompt_tokens
            .div_ceil(self.tokens_per_core)
            .clamp(1, available.max(1))
    }

    /// Expected single-core time (seconds) to process `tokens`.
    pub fn time_for(&self, tokens: usize) -> f64 {
        tokens as f64 / self.tokens_per_sec
    }

    /// Split `tokens` as evenly as possible over `cores` chunks; returns
    /// per-chunk token counts (all within ±1 of each other, no zeros).
    pub fn split_tokens(tokens: usize, cores: usize) -> Vec<usize> {
        assert!(cores > 0);
        let cores = cores.min(tokens.max(1));
        let base = tokens / cores;
        let extra = tokens % cores;
        (0..cores)
            .map(|i| base + usize::from(i < extra))
            .filter(|&n| n > 0)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_on_this_host_is_sane() {
        let p = CoreProfile::measure(256, 16, 10.0);
        assert!(p.tokens_per_sec > 100.0, "rate={}", p.tokens_per_sec);
        assert!(p.tokens_per_core >= 1);
    }

    #[test]
    fn cores_for_ceil_division() {
        let p = CoreProfile::from_rate(4096, 64, 3_200.0, 10.0); // c = 32
        assert_eq!(p.tokens_per_core, 32);
        assert_eq!(p.cores_for(0, 8), 0);
        assert_eq!(p.cores_for(1, 8), 1);
        assert_eq!(p.cores_for(32, 8), 1);
        assert_eq!(p.cores_for(33, 8), 2);
        assert_eq!(p.cores_for(128, 8), 4);
        assert_eq!(p.cores_for(10_000, 8), 8); // capped
    }

    #[test]
    fn split_tokens_balanced_and_complete() {
        for (tokens, cores) in [(128, 4), (7, 3), (1, 5), (100, 7)] {
            let chunks = CoreProfile::split_tokens(tokens, cores);
            assert_eq!(chunks.iter().sum::<usize>(), tokens);
            let mx = *chunks.iter().max().unwrap();
            let mn = *chunks.iter().min().unwrap();
            assert!(mx - mn <= 1, "{chunks:?}");
            assert!(chunks.iter().all(|&c| c > 0));
        }
    }

    #[test]
    fn time_scales_linearly() {
        let p = CoreProfile::from_rate(4096, 64, 1000.0, 10.0);
        assert!((p.time_for(500) - 0.5).abs() < 1e-12);
    }
}
