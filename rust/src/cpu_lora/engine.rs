//! [`CpuLoraEngine`] — the front end of CPU-assisted LoRA serving.
//!
//! Splits a request's L prompt tokens over ⌈L/c⌉ workers (profiling-
//! guided, §4.2), scatters the activation slices through shared memory,
//! and gathers the per-slice `xAB` results. All workers compute
//! concurrently; the scatter/gather cost is what Fig 17/18 measure.

use std::sync::Arc;

use super::profiles::CoreProfile;
use super::worker::{AdapterTable, WorkerPool};
use crate::model::TargetMatrix;

/// CPU-assisted LoRA execution engine.
pub struct CpuLoraEngine {
    pool: WorkerPool,
    profile: CoreProfile,
    hidden: usize,
    max_tokens: usize,
}

impl CpuLoraEngine {
    /// Build an engine with `n_workers` workers at hidden size `hidden`,
    /// each able to hold `max_tokens` tokens, using the given profile
    /// for core allocation.
    pub fn new(
        n_workers: usize,
        hidden: usize,
        max_tokens: usize,
        table: Arc<AdapterTable>,
        profile: CoreProfile,
    ) -> Result<CpuLoraEngine, crate::ipc::shm::ShmError> {
        let pool = WorkerPool::spawn(n_workers, hidden, max_tokens, table)?;
        Ok(CpuLoraEngine {
            pool,
            profile,
            hidden,
            max_tokens,
        })
    }

    /// The worker pool's adapter table.
    pub fn table(&self) -> &Arc<AdapterTable> {
        self.pool.table()
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.pool.len()
    }

    /// The active core profile.
    pub fn profile(&self) -> &CoreProfile {
        &self.profile
    }

    /// Compute `xAB` for `n_tok` tokens against `adapter_id`/`target`,
    /// splitting across ⌈n_tok/c⌉ workers. Returns the n_tok×hidden
    /// adaptation delta.
    pub fn apply(
        &self,
        adapter_id: u64,
        target: TargetMatrix,
        n_tok: usize,
        x: &[f32],
    ) -> Vec<f32> {
        assert_eq!(x.len(), n_tok * self.hidden);
        if n_tok == 0 {
            return Vec::new();
        }
        let cores = self.profile.cores_for(n_tok, self.pool.len());
        let chunks = CoreProfile::split_tokens(n_tok, cores);

        // Scatter.
        let mut tokens_sent = 0usize;
        let mut pending: Vec<(usize, u32, usize)> = Vec::with_capacity(chunks.len());
        for (w, &chunk) in chunks.iter().enumerate() {
            let start = tokens_sent * self.hidden;
            let end = (tokens_sent + chunk) * self.hidden;
            let token =
                self.pool
                    .submit(w, adapter_id, target, chunk, self.hidden, &x[start..end]);
            pending.push((w, token, chunk));
            tokens_sent += chunk;
        }

        // Gather in submission order (results are position-dependent).
        let mut out = Vec::with_capacity(n_tok * self.hidden);
        let mut buf = Vec::new();
        for (w, token, chunk) in pending {
            self.pool.collect(w, token, &mut buf);
            debug_assert_eq!(buf.len(), chunk * self.hidden);
            out.extend_from_slice(&buf);
        }
        out
    }

    /// Largest token count a single worker slot can hold.
    pub fn max_tokens(&self) -> usize {
        self.max_tokens
    }

    /// Apply all three standard targets (Q, K, V) for a prefill slice,
    /// returning the three deltas. This is the per-attention-layer call
    /// the base inference process makes during CPU-assisted prefill.
    pub fn apply_qkv(
        &self,
        adapter_id: u64,
        n_tok: usize,
        x: &[f32],
    ) -> [Vec<f32>; 3] {
        [
            self.apply(adapter_id, TargetMatrix::Q, n_tok, x),
            self.apply(adapter_id, TargetMatrix::K, n_tok, x),
            self.apply(adapter_id, TargetMatrix::V, n_tok, x),
        ]
    }
}

/// The CPU-assisted path of the serving engine: during a cold start the
/// native runtime sources each layer's Q/K/V deltas from this engine,
/// which shards the tokens across the shm worker pool (§4.2) — one
/// `delta` call per (layer, target).
impl crate::runtime::ExternalLora for CpuLoraEngine {
    fn delta(
        &self,
        adapter: u64,
        target: TargetMatrix,
        n_tok: usize,
        x: &[f32],
    ) -> Vec<f32> {
        self.apply(adapter, target, n_tok, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::lora_apply;

    fn engine(workers: usize, hidden: usize, c: usize) -> CpuLoraEngine {
        let table = Arc::new(AdapterTable::new());
        table.install_synthetic(1, hidden, 8);
        // Synthetic profile with budget c tokens/core.
        let profile = CoreProfile::from_rate(hidden, 8, c as f64 * 100.0, 10.0);
        CpuLoraEngine::new(workers, hidden, 256, table, profile).unwrap()
    }

    fn reference(e: &CpuLoraEngine, n_tok: usize, hidden: usize, x: &[f32]) -> Vec<f32> {
        let weights = e.table().get(1).unwrap();
        let ad = &weights[0];
        let mut want = vec![0.0f32; n_tok * hidden];
        let mut scratch = vec![0.0f32; n_tok * ad.rank];
        lora_apply(
            n_tok, hidden, hidden, ad.rank, x, &ad.a, &ad.b, &mut want, &mut scratch,
        );
        want
    }

    #[test]
    fn split_apply_equals_single_core() {
        let hidden = 32;
        let e = engine(4, hidden, 8); // c=8 → 4 workers for 32 tokens
        let n_tok = 32;
        let x: Vec<f32> = (0..n_tok * hidden).map(|i| ((i % 7) as f32) * 0.25).collect();
        let got = e.apply(1, TargetMatrix::Q, n_tok, &x);
        let want = reference(&e, n_tok, hidden, &x);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn uneven_split_preserves_order() {
        let hidden = 16;
        let e = engine(3, hidden, 4); // 10 tokens → 3 workers (4,3,3)
        let n_tok = 10;
        let x: Vec<f32> = (0..n_tok * hidden).map(|i| i as f32 * 0.01).collect();
        let got = e.apply(1, TargetMatrix::V, n_tok, &x);
        let want = {
            let weights = e.table().get(1).unwrap();
            let ad = &weights[2];
            let mut w = vec![0.0f32; n_tok * hidden];
            let mut s = vec![0.0f32; n_tok * ad.rank];
            lora_apply(n_tok, hidden, hidden, ad.rank, &x, &ad.a, &ad.b, &mut w, &mut s);
            w
        };
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn zero_tokens_is_empty() {
        let e = engine(2, 8, 4);
        assert!(e.apply(1, TargetMatrix::Q, 0, &[]).is_empty());
    }

    #[test]
    fn qkv_returns_three_distinct_deltas() {
        let hidden = 16;
        let e = engine(2, hidden, 8);
        let x = vec![1.0f32; hidden];
        let [q, k, v] = e.apply_qkv(1, 1, &x);
        assert_eq!(q.len(), hidden);
        assert_ne!(q, k);
        assert_ne!(k, v);
    }
}
