//! CPU-assisted LoRA serving (paper §4).
//!
//! While an adapter's weights stream host→device (the cold-start window),
//! the prefill-phase LoRA computation `xAB` runs on host cores. This is
//! the live serving path, not a model: [`crate::server::InferenceServer`]
//! with CPU assist enabled sources every cold request's per-layer Q/K/V
//! deltas from [`CpuLoraEngine`] (via [`crate::runtime::ExternalLora`]),
//! keeps the request on this path through decode while the load window
//! runs ([`crate::adapters::AsyncLoader`]), and hands off to the resident
//! `bgmv` path once the adapter's transfer completes (§4.3). The pieces:
//!
//! - [`profiles`] — profiling-guided parallelization (§4.2): measure
//!   single-core token throughput, derive the per-core token budget `c`,
//!   allocate ⌈L/c⌉ cores per request.
//! - [`worker`] — the per-core worker pool fed through the shared-memory
//!   slots of [`crate::ipc::shm`] (isolated-process-ready data plane).
//! - [`engine`] — [`CpuLoraEngine`]: splits a request's tokens across
//!   workers, scatters via shm, gathers `xAB`.
//! - [`device_queue`] — a strict-FIFO device command queue modelling the
//!   CUDA stream, with the paper's *native* (explicit host sync between
//!   memcpy and signal) and *sync-free* (fused async memcpy+signal
//!   command) invocation modes (Fig 8 / Fig 16).

pub mod device_queue;
pub mod engine;
pub mod profiles;
pub mod worker;

pub use device_queue::{DeviceQueue, InvokeMode};
pub use engine::CpuLoraEngine;
pub use profiles::CoreProfile;
pub use worker::{AdapterTable, WorkerPool};
