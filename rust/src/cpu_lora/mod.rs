//! CPU-assisted LoRA serving (paper §4).
//!
//! While an adapter's weights stream host→device (the cold-start window),
//! the prefill-phase LoRA computation `xAB` runs on host cores. The
//! pieces:
//!
//! - [`profiles`] — profiling-guided parallelization (§4.2): measure
//!   single-core token throughput, derive the per-core token budget `c`,
//!   allocate ⌈L/c⌉ cores per request.
//! - [`worker`] — the per-core worker pool fed through the shared-memory
//!   slots of [`crate::ipc::shm`] (isolated-process-ready data plane).
//! - [`engine`] — [`CpuLoraEngine`]: splits a request's tokens across
//!   workers, scatters via shm, gathers `xAB`.
//! - [`device_queue`] — a strict-FIFO device command queue modelling the
//!   CUDA stream, with the paper's *native* (explicit host sync between
//!   memcpy and signal) and *sync-free* (fused async memcpy+signal
//!   command) invocation modes (Fig 8 / Fig 16).

pub mod device_queue;
pub mod engine;
pub mod profiles;
pub mod worker;

pub use device_queue::{DeviceQueue, InvokeMode};
pub use engine::CpuLoraEngine;
pub use profiles::CoreProfile;
pub use worker::{AdapterTable, WorkerPool};
