//! Deployment configuration: GPU specs and server/cluster settings.
//!
//! [`GpuSpec`] carries the per-device numbers the analytical latency
//! model needs (HBM bandwidth, compute, PCIe). Values for A10/A100 are
//! the public datasheet figures; an `effective_*` derating reflects the
//! achievable fraction the paper's measurements imply.

use crate::util::json::{self, Json, JsonError};

/// A GPU device specification for the analytical model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Marketing name.
    pub name: String,
    /// Device memory, bytes.
    pub memory_bytes: f64,
    /// Peak HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Peak fp16 tensor compute, FLOP/s.
    pub flops: f64,
    /// Host→device PCIe bandwidth, bytes/s.
    pub pcie_bw: f64,
    /// Fixed per-transfer latency (driver + allocation), seconds.
    pub pcie_latency: f64,
    /// Achievable fraction of peak memory bandwidth (decode is membw-
    /// bound; ~0.6–0.8 in practice).
    pub mem_eff: f64,
    /// Achievable fraction of peak compute (prefill GEMMs; ~0.4–0.6).
    pub flop_eff: f64,
}

impl GpuSpec {
    /// NVIDIA A10: 24 GB, 600 GB/s, 125 TFLOPS fp16, PCIe 4.0 x16.
    pub fn a10() -> GpuSpec {
        GpuSpec {
            name: "A10".into(),
            memory_bytes: 24e9,
            mem_bw: 600e9,
            flops: 125e12,
            // Effective achievable H2D rate for adapter loads: pageable
            // host memory + per-tensor cudaMalloc/copy overheads put real
            // frameworks far below the PCIe 4.0 x16 peak — calibrated so
            // a rank-64 Q/K/V adapter (~100 MiB) costs ~22 ms, matching
            // Fig 3-Right's "a few to tens of ms".
            pcie_bw: 6e9,
            pcie_latency: 5e-3,
            mem_eff: 0.7,
            flop_eff: 0.45,
        }
    }

    /// NVIDIA A100-80G: 80 GB, 2 TB/s, 312 TFLOPS fp16, PCIe 4.0 x16.
    pub fn a100() -> GpuSpec {
        GpuSpec {
            name: "A100".into(),
            memory_bytes: 80e9,
            mem_bw: 2.0e12,
            flops: 312e12,
            pcie_bw: 8e9,
            pcie_latency: 5e-3,
            mem_eff: 0.75,
            flop_eff: 0.5,
        }
    }

    /// Look up by name.
    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name.to_ascii_lowercase().as_str() {
            "a10" => Some(Self::a10()),
            "a100" => Some(Self::a100()),
            _ => None,
        }
    }

    /// Effective memory bandwidth (bytes/s).
    pub fn eff_mem_bw(&self) -> f64 {
        self.mem_bw * self.mem_eff
    }

    /// Effective compute (FLOP/s).
    pub fn eff_flops(&self) -> f64 {
        self.flops * self.flop_eff
    }

    /// Host→device transfer time for `bytes` (seconds).
    pub fn h2d_time(&self, bytes: f64) -> f64 {
        self.pcie_latency + bytes / self.pcie_bw
    }
}

/// Configuration for one inference server instance.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Base model name (see [`crate::model::LlamaConfig::by_name`]).
    pub model: String,
    /// GPU spec name.
    pub gpu: String,
    /// Number of GPUs (tensor parallel degree).
    pub tp: usize,
    /// Host CPU cores available to CPU-LoRA workers.
    pub cpu_cores: usize,
    /// Device memory fraction reserved for KV cache.
    pub kv_fraction: f64,
    /// Max requests in one running batch.
    pub max_batch: usize,
    /// GPU LoRA kernel: "bgmv" (padded) or "mbgmv" (padding-free).
    pub kernel: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            model: "llama2-7b".into(),
            gpu: "a10".into(),
            tp: 1,
            cpu_cores: 8,
            kv_fraction: 0.3,
            max_batch: 64,
            kernel: "bgmv".into(),
        }
    }
}

impl ServerConfig {
    /// Parse from a JSON object (all keys optional, defaults applied).
    pub fn from_json(j: &Json) -> Result<ServerConfig, JsonError> {
        let mut cfg = ServerConfig::default();
        if let Some(v) = j.get("model").and_then(Json::as_str) {
            cfg.model = v.to_string();
        }
        if let Some(v) = j.get("gpu").and_then(Json::as_str) {
            cfg.gpu = v.to_string();
        }
        if let Some(v) = j.get("tp").and_then(Json::as_usize) {
            cfg.tp = v;
        }
        if let Some(v) = j.get("cpu_cores").and_then(Json::as_usize) {
            cfg.cpu_cores = v;
        }
        if let Some(v) = j.get("kv_fraction").and_then(Json::as_f64) {
            cfg.kv_fraction = v;
        }
        if let Some(v) = j.get("max_batch").and_then(Json::as_usize) {
            cfg.max_batch = v;
        }
        if let Some(v) = j.get("kernel").and_then(Json::as_str) {
            cfg.kernel = v.to_string();
        }
        Ok(cfg)
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("model", json::s(&self.model)),
            ("gpu", json::s(&self.gpu)),
            ("tp", json::num(self.tp as f64)),
            ("cpu_cores", json::num(self.cpu_cores as f64)),
            ("kv_fraction", json::num(self.kv_fraction)),
            ("max_batch", json::num(self.max_batch as f64)),
            ("kernel", json::s(&self.kernel)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_lookup() {
        assert_eq!(GpuSpec::by_name("a10").unwrap().name, "A10");
        assert_eq!(GpuSpec::by_name("A100").unwrap().name, "A100");
        assert!(GpuSpec::by_name("h100").is_none());
    }

    #[test]
    fn h2d_has_floor_plus_linear() {
        let g = GpuSpec::a10();
        let t_small = g.h2d_time(1e6);
        let t_big = g.h2d_time(100e6);
        assert!(t_small >= g.pcie_latency);
        assert!(t_big > t_small);
        // 100 MB at 6 GB/s effective ≈ 16.7 ms + 5 ms floor ≈ 22 ms —
        // the Fig 3-Right band for a rank-64 adapter.
        assert!((t_big - 21.7e-3).abs() < 1e-3, "t_big={t_big}");
    }

    #[test]
    fn server_config_roundtrip() {
        let cfg = ServerConfig {
            model: "llama2-13b".into(),
            tp: 2,
            kernel: "mbgmv".into(),
            ..Default::default()
        };
        let j = cfg.to_json();
        let back = ServerConfig::from_json(&j).unwrap();
        assert_eq!(back.model, "llama2-13b");
        assert_eq!(back.tp, 2);
        assert_eq!(back.kernel, "mbgmv");
    }

    #[test]
    fn from_json_applies_defaults() {
        let j = Json::parse(r#"{"model": "tiny"}"#).unwrap();
        let cfg = ServerConfig::from_json(&j).unwrap();
        assert_eq!(cfg.model, "tiny");
        assert_eq!(cfg.max_batch, 64);
    }
}
