//! Model descriptions and analytical cost math.
//!
//! [`LlamaConfig`] captures the transformer shapes from the paper's
//! Table 2 (Llama2-7B/13B/70B) plus the runnable TinyLlama used by the
//! functional PJRT path. The FLOPs/bytes accounting here drives the
//! roofline GPU latency model in [`crate::sim::gpu`] and the adapter
//! memory/cold-start math in [`crate::adapters`].

pub mod lora;

pub use lora::{LoraSpec, TargetMatrix};

/// Bytes per parameter for the simulated deployment (fp16 like the paper).
pub const BYTES_PER_PARAM: f64 = 2.0;

/// A Llama-family transformer configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct LlamaConfig {
    /// Human-readable name ("llama2-7b", "tiny", ...).
    pub name: String,
    /// Hidden dimension H.
    pub hidden: usize,
    /// Number of transformer layers.
    pub layers: usize,
    /// Number of attention heads.
    pub heads: usize,
    /// Number of KV heads (grouped-query attention; = heads for MHA).
    pub kv_heads: usize,
    /// FFN intermediate size H'.
    pub intermediate: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Max sequence length supported by the KV cache.
    pub max_seq: usize,
}

impl LlamaConfig {
    /// Llama2-7B (Table 2: hidden 4096, 32 layers; served on 1×A10).
    pub fn llama2_7b() -> Self {
        Self {
            name: "llama2-7b".into(),
            hidden: 4096,
            layers: 32,
            heads: 32,
            kv_heads: 32,
            intermediate: 11008,
            vocab: 32000,
            max_seq: 4096,
        }
    }

    /// Llama2-13B (Table 2: hidden 5120, 40 layers; 2×A10 tensor-parallel).
    pub fn llama2_13b() -> Self {
        Self {
            name: "llama2-13b".into(),
            hidden: 5120,
            layers: 40,
            heads: 40,
            kv_heads: 40,
            intermediate: 13824,
            vocab: 32000,
            max_seq: 4096,
        }
    }

    /// Llama2-70B (Table 2: hidden 8192, 80 layers; 4×A100, GQA kv=8).
    pub fn llama2_70b() -> Self {
        Self {
            name: "llama2-70b".into(),
            hidden: 8192,
            layers: 80,
            heads: 64,
            kv_heads: 8,
            intermediate: 28672,
            vocab: 32000,
            max_seq: 4096,
        }
    }

    /// The tiny, actually-runnable model compiled to HLO artifacts by
    /// `python/compile/aot.py` and executed through PJRT in the e2e
    /// example and integration tests. Must stay in sync with
    /// `python/compile/model.py::TINY`.
    pub fn tiny() -> Self {
        Self {
            name: "tiny".into(),
            hidden: 256,
            layers: 4,
            heads: 8,
            kv_heads: 8,
            intermediate: 688,
            vocab: 1024,
            max_seq: 256,
        }
    }

    /// Look up a named config.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama2-7b" | "7b" => Some(Self::llama2_7b()),
            "llama2-13b" | "13b" => Some(Self::llama2_13b()),
            "llama2-70b" | "70b" => Some(Self::llama2_70b()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// Head dimension.
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Total parameter count (weights only, incl. embeddings + lm head).
    pub fn param_count(&self) -> f64 {
        let h = self.hidden as f64;
        let kv_h = (self.kv_heads * self.head_dim()) as f64;
        let inter = self.intermediate as f64;
        let per_layer =
            // Wq, Wo: H×H each; Wk, Wv: H×kv_h each.
            2.0 * h * h + 2.0 * h * kv_h
            // SwiGLU FFN: gate, up (H×H'), down (H'×H).
            + 3.0 * h * inter;
        let embed = 2.0 * self.vocab as f64 * h; // tied-ish: embed + lm_head
        per_layer * self.layers as f64 + embed
    }

    /// Model weight bytes at fp16.
    pub fn weight_bytes(&self) -> f64 {
        self.param_count() * BYTES_PER_PARAM
    }

    /// KV-cache bytes per token (all layers, fp16).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 // K and V
            * (self.kv_heads * self.head_dim()) as f64
            * self.layers as f64
            * BYTES_PER_PARAM
    }

    /// Forward-pass FLOPs for `n_tokens` processed in one iteration with
    /// total attended context `ctx_tokens` (per request, summed outside).
    /// Uses the standard 2·params·tokens approximation for the dense part
    /// plus the attention score/value FLOPs that scale with context.
    pub fn fwd_flops(&self, n_tokens: f64, ctx_tokens: f64) -> f64 {
        let h = self.hidden as f64;
        let dense = 2.0 * self.param_count() * n_tokens;
        // QK^T and attn·V per layer: 2 · 2 · n · ctx · H
        let attn = 4.0 * self.layers as f64 * n_tokens * ctx_tokens * h;
        dense + attn
    }

    /// Bytes of weights + KV that one decode iteration must stream from
    /// device memory (batch-shared weights counted once).
    pub fn decode_bytes(&self, batch: f64, avg_ctx: f64) -> f64 {
        self.weight_bytes() + batch * avg_ctx * self.kv_bytes_per_token()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_are_plausible() {
        // Within 15% of the nominal sizes.
        let b7 = LlamaConfig::llama2_7b().param_count() / 1e9;
        assert!((6.0..8.0).contains(&b7), "7B params = {b7}B");
        let b13 = LlamaConfig::llama2_13b().param_count() / 1e9;
        assert!((11.5..14.5).contains(&b13), "13B params = {b13}B");
        let b70 = LlamaConfig::llama2_70b().param_count() / 1e9;
        assert!((62.0..76.0).contains(&b70), "70B params = {b70}B");
    }

    #[test]
    fn kv_bytes_match_paper_equivalence() {
        // Paper §2.3: a rank-64 adapter over Wq/Wk/Wv of Llama2-7B is
        // ~100 MiB ≈ the KV cache of 200 tokens. Check the 200-token KV
        // size is in that ballpark.
        let cfg = LlamaConfig::llama2_7b();
        let kv200 = cfg.kv_bytes_per_token() * 200.0 / (1024.0 * 1024.0);
        assert!((80.0..130.0).contains(&kv200), "kv200 = {kv200} MiB");
    }

    #[test]
    fn by_name_roundtrip() {
        for name in ["llama2-7b", "llama2-13b", "llama2-70b", "tiny"] {
            assert_eq!(LlamaConfig::by_name(name).unwrap().name, name);
        }
        assert!(LlamaConfig::by_name("gpt-5").is_none());
    }

    #[test]
    fn flops_monotonic_in_tokens_and_ctx() {
        let cfg = LlamaConfig::llama2_7b();
        assert!(cfg.fwd_flops(2.0, 100.0) > cfg.fwd_flops(1.0, 100.0));
        assert!(cfg.fwd_flops(1.0, 200.0) > cfg.fwd_flops(1.0, 100.0));
    }

    #[test]
    fn head_dim_divides() {
        for cfg in [
            LlamaConfig::llama2_7b(),
            LlamaConfig::llama2_13b(),
            LlamaConfig::llama2_70b(),
            LlamaConfig::tiny(),
        ] {
            assert_eq!(cfg.head_dim() * cfg.heads, cfg.hidden, "{}", cfg.name);
        }
    }
}
