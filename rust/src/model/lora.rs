//! LoRA adapter descriptions: rank, target matrices, and memory math.

use super::{LlamaConfig, BYTES_PER_PARAM};

/// Which base weight matrix an adapter pair (A, B) applies to.
/// The paper follows the standard setting: adapters on W_Q, W_K, W_V.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TargetMatrix {
    Q,
    K,
    V,
    O,
}

impl TargetMatrix {
    /// The standard paper configuration: Q, K, V.
    pub fn standard() -> Vec<TargetMatrix> {
        vec![TargetMatrix::Q, TargetMatrix::K, TargetMatrix::V]
    }
}

/// A LoRA adapter specification (metadata; weights live in
/// [`crate::adapters::HostRepository`]).
#[derive(Debug, Clone, PartialEq)]
pub struct LoraSpec {
    /// Globally unique adapter id.
    pub id: u64,
    /// LoRA rank r.
    pub rank: usize,
    /// Base weights this adapter applies to.
    pub targets: Vec<TargetMatrix>,
    /// Name of the base model this adapter was trained from.
    pub base_model: String,
}

impl LoraSpec {
    /// Standard Q/K/V adapter of rank `rank` for `base_model`.
    pub fn standard(id: u64, rank: usize, base_model: &str) -> Self {
        Self {
            id,
            rank,
            targets: TargetMatrix::standard(),
            base_model: base_model.to_string(),
        }
    }

    /// Parameter count: per layer and target, A∈R^{H×r} + B∈R^{r×H}.
    pub fn param_count(&self, cfg: &LlamaConfig) -> f64 {
        let h = cfg.hidden as f64;
        let r = self.rank as f64;
        self.targets.len() as f64 * cfg.layers as f64 * (h * r + r * h)
    }

    /// Weight bytes at fp16 — what must cross PCIe on a cold start.
    pub fn weight_bytes(&self, cfg: &LlamaConfig) -> f64 {
        self.param_count(cfg) * BYTES_PER_PARAM
    }

    /// FLOPs for applying this adapter to `n_tokens` tokens:
    /// per target+layer, x·A (2·n·H·r) + (xA)·B (2·n·r·H).
    pub fn apply_flops(&self, cfg: &LlamaConfig, n_tokens: f64) -> f64 {
        let h = cfg.hidden as f64;
        let r = self.rank as f64;
        self.targets.len() as f64 * cfg.layers as f64 * 4.0 * n_tokens * h * r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank64_adapter_is_about_100mib_on_7b() {
        // Paper §2.3: a single rank-64 adapter on Wq/Wk/Wv of Llama2-7B
        // demands ~100 MiB.
        let cfg = LlamaConfig::llama2_7b();
        let spec = LoraSpec::standard(1, 64, &cfg.name);
        let mib = spec.weight_bytes(&cfg) / (1024.0 * 1024.0);
        assert!((80.0..130.0).contains(&mib), "adapter = {mib} MiB");
    }

    #[test]
    fn bytes_scale_linearly_with_rank() {
        let cfg = LlamaConfig::llama2_7b();
        let b32 = LoraSpec::standard(1, 32, &cfg.name).weight_bytes(&cfg);
        let b64 = LoraSpec::standard(2, 64, &cfg.name).weight_bytes(&cfg);
        assert!((b64 / b32 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn adapter_flops_tiny_vs_base() {
        // Paper §2.1: xAB is orders of magnitude cheaper than xW.
        let cfg = LlamaConfig::llama2_7b();
        let spec = LoraSpec::standard(1, 64, &cfg.name);
        let ratio = spec.apply_flops(&cfg, 1.0) / cfg.fwd_flops(1.0, 1.0);
        assert!(ratio < 0.05, "ratio = {ratio}");
    }
}
