//! Hand-rolled SHA-256 (FIPS 180-4) on `std` only.
//!
//! The artifact store addresses every blob by its SHA-256 digest, so the
//! hash must be available without pulling a crypto crate — the repo's
//! zero-dependency discipline. This is the straightforward single-block
//! compression-function implementation: no lookup-table tricks, no
//! unsafe, ~40 MB/s in release mode — far above what the store's
//! kilobyte-to-megabyte adapter blobs need.
//!
//! Verified against the FIPS test vectors (empty string, `"abc"`, the
//! 448-bit message) and a million-`a` stress vector in the tests below.

/// Round constants: the first 32 bits of the fractional parts of the
/// cube roots of the first 64 primes.
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state: the first 32 bits of the fractional parts of the
/// square roots of the first 8 primes.
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Incremental SHA-256 hasher. `update` in any chunking, then
/// `finalize` — the digest is independent of the chunk boundaries, which
/// is exactly what lets the wire layer hash a blob chunk-by-chunk as it
/// streams in.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Partial block awaiting 64 accumulated bytes.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message bytes seen (the padded length field).
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Sha256::new()
    }
}

impl Sha256 {
    /// Fresh hasher.
    pub fn new() -> Sha256 {
        Sha256 {
            state: H0,
            buf: [0u8; 64],
            buf_len: 0,
            total: 0,
        }
    }

    /// Absorb `data`.
    pub fn update(&mut self, data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        let mut rest = data;
        // Top up a partial block first.
        if self.buf_len > 0 {
            let need = 64 - self.buf_len;
            let take = need.min(rest.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&rest[..take]);
            self.buf_len += take;
            rest = &rest[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        // Whole blocks straight from the input.
        while rest.len() >= 64 {
            let (block, tail) = rest.split_at(64);
            let mut b = [0u8; 64];
            b.copy_from_slice(block);
            self.compress(&b);
            rest = tail;
        }
        // Stash the tail.
        if !rest.is_empty() {
            self.buf[..rest.len()].copy_from_slice(rest);
            self.buf_len = rest.len();
        }
    }

    /// Pad, run the final block(s), and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        // 0x80 terminator, zero padding to 56 mod 64, then the 64-bit
        // big-endian message length.
        self.update_padding(bit_len);
        let mut out = [0u8; 32];
        for (i, word) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    fn update_padding(&mut self, bit_len: u64) {
        let mut pad = [0u8; 72];
        pad[0] = 0x80;
        // Bytes of padding so that (total + pad_len) % 64 == 56.
        let pad_len = 1 + ((55usize.wrapping_sub(self.total as usize)) % 64);
        pad[pad_len..pad_len + 8].copy_from_slice(&bit_len.to_be_bytes());
        // Route through the normal block machinery (total is already
        // final; the extra wrapping_add it does is discarded).
        let mut rest = &pad[..pad_len + 8];
        while !rest.is_empty() {
            let need = 64 - self.buf_len;
            let take = need.min(rest.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&rest[..take]);
            self.buf_len += take;
            rest = &rest[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
    }

    /// The FIPS 180-4 compression function over one 512-bit block.
    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a.wrapping_add(t2);
            a = t1.wrapping_add(t2);
        }
        let add = [a, b, c, d, e, f, g, h];
        for (s, x) in self.state.iter_mut().zip(add) {
            *s = s.wrapping_add(x);
        }
    }
}

/// One-shot digest.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// Lowercase hex of a digest — the form blob filenames, manifests, and
/// wire frames carry.
pub fn to_hex(digest: &[u8; 32]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(64);
    for &b in digest {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 0xf) as usize] as char);
    }
    s
}

/// One-shot hex digest: the store's canonical content address.
pub fn hex_digest(data: &[u8]) -> String {
    to_hex(&sha256(data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fips_vectors() {
        assert_eq!(
            hex_digest(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex_digest(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex_digest(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a_stress_vector() {
        let mut h = Sha256::new();
        let chunk = [b'a'; 997]; // deliberately not block-aligned
        let mut left = 1_000_000usize;
        while left > 0 {
            let n = left.min(chunk.len());
            h.update(&chunk[..n]);
            left -= n;
        }
        assert_eq!(
            to_hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn digest_is_chunking_independent() {
        let data: Vec<u8> = (0..4096u32).map(|i| (i * 31 % 251) as u8).collect();
        let whole = hex_digest(&data);
        for chunk in [1usize, 7, 63, 64, 65, 1000] {
            let mut h = Sha256::new();
            for c in data.chunks(chunk) {
                h.update(c);
            }
            assert_eq!(to_hex(&h.finalize()), whole, "chunk size {chunk}");
        }
    }

    #[test]
    fn hex_is_lowercase_64_chars() {
        let d = hex_digest(b"caraserve");
        assert_eq!(d.len(), 64);
        assert!(d.bytes().all(|b| b.is_ascii_hexdigit() && !b.is_ascii_uppercase()));
    }
}
