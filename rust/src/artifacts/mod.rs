//! Content-addressed adapter artifact store (the deployment pipeline).
//!
//! Before this subsystem, every cross-server install re-seeded
//! *synthetic* weights on the target — the cluster had no way to move
//! actual adapter bytes between processes. The store models the OCI
//! artifact shape: an adapter is a hand-rolled-JSON **manifest**
//! ([`Manifest`]: adapter id, rank, base model, per-tensor blob digests
//! + sizes) pointing at **digest-addressed blobs** (raw little-endian
//! f32 runs of each target's `(A, B)` pair, addressed by their
//! [`sha256`] hex digest). Two adapters sharing a tensor share the blob
//! file — dedup falls out of content addressing; integrity falls out of
//! re-hashing on every read.
//!
//! On disk a store is a directory:
//!
//! ```text
//! <root>/index.json        adapter id → manifest digest (byte-stable
//!                          re-saves, like GlobalRegistry::save)
//! <root>/blobs/<digest>    tensor blobs AND manifest documents, both
//!                          addressed by content
//! ```
//!
//! Refcounted GC: a blob is *live* while any indexed manifest references
//! it (manifest documents are live while the index references them).
//! [`ArtifactStore::gc`] deletes only dead blob files, so a placed
//! adapter can never lose its weights to collection.
//!
//! The wire layer ([`crate::remote::wire`]) streams blobs between
//! processes in digest-verified chunks; [`ArtifactStore::ingest_chunk`]
//! is the receiving half (strictly sequential offsets, whole-blob digest
//! check before the file is committed). [`crate::server::InferenceServer`]
//! sources install weights from an attached store (counted by
//! [`ArtifactStore::store_hits`]) and only falls back to synthetic
//! seeding when the store has no manifest for the adapter — which is how
//! the acceptance assertion "zero synthetic re-seeding on a migration
//! target" is made checkable over the wire.

pub mod sha256;

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::kernels::bgmv::AdapterWeights;
use crate::util::json::{self, Json};

pub use sha256::{hex_digest, Sha256};

/// Canonical per-target blob order in every manifest: Q, K, V, O.
pub const TARGET_NAMES: [&str; 4] = ["q", "k", "v", "o"];

/// Typed store failure. Every variant is an outcome the caller can
/// branch on — corrupt data is a *rejection*, never a panic.
#[derive(Debug, Clone, PartialEq)]
pub enum StoreError {
    /// Filesystem error, with the operation that hit it.
    Io { op: &'static str, detail: String },
    /// A blob's bytes no longer hash to its address.
    Corrupt { digest: String, got: String },
    /// A referenced blob is not in the store.
    MissingBlob { digest: String },
    /// No manifest for this adapter in the index.
    NotFound { adapter: u64 },
    /// A manifest or index document failed to parse or validate.
    BadManifest { detail: String },
    /// A blob's size disagrees with its manifest entry (or a chunked
    /// transfer overran its declared total).
    SizeMismatch {
        digest: String,
        expected: u64,
        got: u64,
    },
    /// A streamed chunk arrived at the wrong offset.
    ChunkOutOfOrder {
        digest: String,
        expected: u64,
        got: u64,
    },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io { op, detail } => write!(f, "artifact store {op}: {detail}"),
            StoreError::Corrupt { digest, got } => {
                write!(f, "blob {digest} is corrupt (content hashes to {got})")
            }
            StoreError::MissingBlob { digest } => write!(f, "blob {digest} not in store"),
            StoreError::NotFound { adapter } => {
                write!(f, "adapter {adapter} not in artifact store")
            }
            StoreError::BadManifest { detail } => write!(f, "bad manifest: {detail}"),
            StoreError::SizeMismatch {
                digest,
                expected,
                got,
            } => write!(f, "blob {digest} size {got} != declared {expected}"),
            StoreError::ChunkOutOfOrder {
                digest,
                expected,
                got,
            } => write!(f, "chunk for {digest} at offset {got}, expected {expected}"),
        }
    }
}

impl std::error::Error for StoreError {}

fn io_err(op: &'static str, e: std::io::Error) -> StoreError {
    StoreError::Io {
        op,
        detail: e.to_string(),
    }
}

/// One tensor blob a manifest references.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlobDesc {
    /// Which target matrix pair this blob holds (`"q" | "k" | "v" | "o"`).
    pub target: String,
    /// SHA-256 hex of the blob bytes — its address under `blobs/`.
    pub digest: String,
    /// Blob size in bytes (`8 · hidden · rank`: the `(A, B)` f32 pair).
    pub size: u64,
}

/// A content-addressed adapter description: what [`ArtifactStore`]
/// indexes and what [`crate::remote::wire`] ships as JSON text (the
/// text's digest is the manifest's identity, so receivers re-verify it
/// byte-for-byte).
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub adapter: u64,
    pub rank: usize,
    pub base_model: String,
    /// Per-target blobs, always in [`TARGET_NAMES`] order.
    pub blobs: Vec<BlobDesc>,
}

impl Manifest {
    /// Canonical JSON document. Field order is fixed and the printer is
    /// deterministic, so equal manifests serialize to equal bytes —
    /// the digest is stable across processes and re-saves.
    pub fn to_json(&self) -> Json {
        let blobs: Vec<Json> = self
            .blobs
            .iter()
            .map(|b| {
                json::obj(vec![
                    ("target", json::s(&b.target)),
                    ("digest", json::s(&b.digest)),
                    ("size", json::num(b.size as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("adapter", json::num(self.adapter as f64)),
            ("rank", json::num(self.rank as f64)),
            ("base_model", json::s(&self.base_model)),
            ("blobs", Json::Arr(blobs)),
        ])
    }

    /// The canonical serialized form whose hash addresses this manifest.
    pub fn canonical(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// The manifest's content address.
    pub fn digest(&self) -> String {
        hex_digest(self.canonical().as_bytes())
    }

    /// Parse a manifest document and validate its shape: four blobs in
    /// [`TARGET_NAMES`] order, 64-char hex digests, sizes consistent
    /// with one `(A, B)` f32 pair of the declared rank.
    pub fn parse(text: &str) -> Result<Manifest, StoreError> {
        let bad = |detail: String| StoreError::BadManifest { detail };
        let j = Json::parse(text).map_err(|e| bad(e.to_string()))?;
        let adapter = j
            .get("adapter")
            .and_then(Json::as_f64)
            .ok_or_else(|| bad("missing adapter id".into()))? as u64;
        let rank = j
            .get("rank")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("missing rank".into()))?;
        if rank == 0 {
            return Err(bad("rank 0".into()));
        }
        let base_model = j
            .get("base_model")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing base_model".into()))?
            .to_string();
        let raw = j
            .get("blobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing blobs".into()))?;
        if raw.len() != TARGET_NAMES.len() {
            return Err(bad(format!("{} blobs, expected 4", raw.len())));
        }
        let mut blobs = Vec::with_capacity(4);
        for (i, item) in raw.iter().enumerate() {
            let target = item
                .get("target")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("blob missing target".into()))?
                .to_string();
            if target != TARGET_NAMES[i] {
                return Err(bad(format!(
                    "blob {i} targets {target:?}, expected {:?}",
                    TARGET_NAMES[i]
                )));
            }
            let digest = item
                .get("digest")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("blob missing digest".into()))?
                .to_string();
            if !is_hex_digest(&digest) {
                return Err(bad(format!("blob digest {digest:?} is not 64-char hex")));
            }
            let size = item
                .get("size")
                .and_then(Json::as_f64)
                .ok_or_else(|| bad("blob missing size".into()))? as u64;
            // One (A, B) pair: 2 · hidden · rank f32s = 8 · hidden · rank
            // bytes, so the size must be a positive multiple of 8 · rank.
            if size == 0 || size % (8 * rank as u64) != 0 {
                return Err(bad(format!(
                    "blob size {size} not a positive multiple of 8·rank ({rank})"
                )));
            }
            blobs.push(BlobDesc {
                target,
                digest,
                size,
            });
        }
        Ok(Manifest {
            adapter,
            rank,
            base_model,
            blobs,
        })
    }

    /// The hidden dimension the blob sizes imply (all four targets must
    /// agree — [`Manifest::parse`] guarantees divisibility, this checks
    /// agreement).
    pub fn hidden(&self) -> Result<usize, StoreError> {
        let h0 = (self.blobs[0].size / (8 * self.rank as u64)) as usize;
        for b in &self.blobs {
            if b.size != 8 * self.rank as u64 * h0 as u64 {
                return Err(StoreError::BadManifest {
                    detail: format!("blob sizes disagree on hidden dim (target {})", b.target),
                });
            }
        }
        Ok(h0)
    }
}

fn is_hex_digest(s: &str) -> bool {
    s.len() == 64 && s.bytes().all(|b| matches!(b, b'0'..=b'9' | b'a'..=b'f'))
}

/// Serialize one target's `(A, B)` pair as the raw little-endian f32
/// run its blob holds. Inverse of [`weights_from_blob`]; both are
/// bitwise-lossless, which is what keeps token streams computed from
/// transferred weights identical to the publisher's.
pub fn blob_bytes(w: &AdapterWeights) -> Vec<u8> {
    let mut out = Vec::with_capacity((w.a.len() + w.b.len()) * 4);
    for x in w.a.iter().chain(w.b.iter()) {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Rebuild one target's weights from its blob bytes.
pub fn weights_from_blob(
    bytes: &[u8],
    hidden: usize,
    rank: usize,
) -> Result<AdapterWeights, StoreError> {
    let a_len = hidden * rank;
    if bytes.len() != 8 * a_len {
        return Err(StoreError::SizeMismatch {
            digest: String::new(),
            expected: 8 * a_len as u64,
            got: bytes.len() as u64,
        });
    }
    let floats: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(AdapterWeights {
        rank,
        a: floats[..a_len].to_vec(),
        b: floats[a_len..].to_vec(),
        h1: hidden,
        h2: hidden,
    })
}

/// A blob mid-stream: chunks accepted so far plus the declared total.
struct Staged {
    total: u64,
    buf: Vec<u8>,
}

/// The filesystem-backed content-addressed store. Not internally
/// synchronized — share it as `Arc<Mutex<ArtifactStore>>` (the engine
/// and the wire dispatch do).
pub struct ArtifactStore {
    root: PathBuf,
    /// adapter id → manifest digest (what `index.json` persists).
    index: BTreeMap<u64, String>,
    /// manifest digest → parsed manifest, for every indexed adapter.
    manifests: BTreeMap<String, Manifest>,
    /// Blobs mid-transfer (nothing on disk until complete + verified).
    staging: BTreeMap<String, Staged>,
    /// Successful weight loads served from this store (the acceptance
    /// counter: a migration target with `store_hits > 0` and zero
    /// synthetic seeds installed real transferred weights).
    hits: AtomicU64,
}

impl ArtifactStore {
    /// Open (or create) a store rooted at `root`.
    pub fn open(root: &Path) -> Result<ArtifactStore, StoreError> {
        std::fs::create_dir_all(root.join("blobs")).map_err(|e| io_err("create", e))?;
        let mut store = ArtifactStore {
            root: root.to_path_buf(),
            index: BTreeMap::new(),
            manifests: BTreeMap::new(),
            staging: BTreeMap::new(),
            hits: AtomicU64::new(0),
        };
        let index_path = store.index_path();
        if index_path.exists() {
            let text =
                std::fs::read_to_string(&index_path).map_err(|e| io_err("read index", e))?;
            let j = Json::parse(&text).map_err(|e| StoreError::BadManifest {
                detail: format!("index.json: {e}"),
            })?;
            let entries = j
                .get("adapters")
                .and_then(Json::as_arr)
                .ok_or_else(|| StoreError::BadManifest {
                    detail: "index.json missing adapters".into(),
                })?;
            for item in entries {
                let id = item
                    .get("id")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| StoreError::BadManifest {
                        detail: "index entry missing id".into(),
                    })? as u64;
                let digest = item
                    .get("manifest")
                    .and_then(Json::as_str)
                    .ok_or_else(|| StoreError::BadManifest {
                        detail: "index entry missing manifest digest".into(),
                    })?
                    .to_string();
                // Loading re-verifies the manifest document against its
                // address — a tampered index or manifest is a typed
                // rejection at open, not a later surprise.
                let manifest = store.read_manifest(&digest)?;
                store.index.insert(id, digest.clone());
                store.manifests.insert(digest, manifest);
            }
        }
        Ok(store)
    }

    fn index_path(&self) -> PathBuf {
        self.root.join("index.json")
    }

    /// The file a digest addresses.
    pub fn blob_path(&self, digest: &str) -> PathBuf {
        self.root.join("blobs").join(digest)
    }

    /// Is a blob present (committed, not merely staged)?
    pub fn has_blob(&self, digest: &str) -> bool {
        is_hex_digest(digest) && self.blob_path(digest).exists()
    }

    /// Read a blob and verify it still hashes to its address.
    pub fn read_blob(&self, digest: &str) -> Result<Vec<u8>, StoreError> {
        if !self.has_blob(digest) {
            return Err(StoreError::MissingBlob {
                digest: digest.to_string(),
            });
        }
        let bytes = std::fs::read(self.blob_path(digest)).map_err(|e| io_err("read blob", e))?;
        let got = hex_digest(&bytes);
        if got != digest {
            return Err(StoreError::Corrupt {
                digest: digest.to_string(),
                got,
            });
        }
        Ok(bytes)
    }

    /// Store bytes under their content address. Writing an already-
    /// present blob is a no-op — the dedup path: the second adapter
    /// referencing a shared tensor stores nothing.
    pub fn put_blob(&mut self, bytes: &[u8]) -> Result<String, StoreError> {
        let digest = hex_digest(bytes);
        let path = self.blob_path(&digest);
        if !path.exists() {
            std::fs::write(&path, bytes).map_err(|e| io_err("write blob", e))?;
        }
        Ok(digest)
    }

    /// One chunk of a blob, plus the blob's total size — the serving
    /// half of the wire transfer. The whole blob is re-verified on every
    /// call (blobs are small; integrity beats cleverness here).
    pub fn chunk_of(
        &self,
        digest: &str,
        offset: u64,
        len: usize,
    ) -> Result<(Vec<u8>, u64), StoreError> {
        let bytes = self.read_blob(digest)?;
        let total = bytes.len() as u64;
        if offset > total {
            return Err(StoreError::ChunkOutOfOrder {
                digest: digest.to_string(),
                expected: total,
                got: offset,
            });
        }
        let start = offset as usize;
        let end = (start + len).min(bytes.len());
        Ok((bytes[start..end].to_vec(), total))
    }

    /// Accept one streamed chunk (strictly sequential offsets). On the
    /// final chunk the assembled bytes are verified against `digest`
    /// and committed to disk; `Ok(true)` means the blob is now stored.
    /// Any error drops the staging buffer — a corrupt stream can be
    /// retried from offset 0.
    pub fn ingest_chunk(
        &mut self,
        digest: &str,
        offset: u64,
        total: u64,
        bytes: &[u8],
    ) -> Result<bool, StoreError> {
        if !is_hex_digest(digest) {
            return Err(StoreError::BadManifest {
                detail: format!("chunk digest {digest:?} is not 64-char hex"),
            });
        }
        if self.has_blob(digest) {
            // Already committed (dedup): accept and ignore the bytes.
            return Ok(true);
        }
        let (have, declared) = match self.staging.get(digest) {
            Some(s) => (s.buf.len() as u64, s.total),
            None => {
                self.staging.insert(
                    digest.to_string(),
                    Staged {
                        total,
                        buf: Vec::new(),
                    },
                );
                (0, total)
            }
        };
        // Any protocol violation drops the staging buffer so the sender
        // can retry from offset 0.
        if declared != total {
            self.staging.remove(digest);
            return Err(StoreError::SizeMismatch {
                digest: digest.to_string(),
                expected: declared,
                got: total,
            });
        }
        if have != offset {
            self.staging.remove(digest);
            return Err(StoreError::ChunkOutOfOrder {
                digest: digest.to_string(),
                expected: have,
                got: offset,
            });
        }
        if have + bytes.len() as u64 > total {
            self.staging.remove(digest);
            return Err(StoreError::SizeMismatch {
                digest: digest.to_string(),
                expected: total,
                got: have + bytes.len() as u64,
            });
        }
        let done = {
            let staged = match self.staging.get_mut(digest) {
                Some(s) => s,
                None => {
                    // Unreachable: the entry was ensured above.
                    return Err(StoreError::MissingBlob {
                        digest: digest.to_string(),
                    });
                }
            };
            staged.buf.extend_from_slice(bytes);
            staged.buf.len() as u64 == total
        };
        if done {
            let buf = self.staging.remove(digest).map(|s| s.buf).unwrap_or_default();
            let got = hex_digest(&buf);
            if got != digest {
                return Err(StoreError::Corrupt {
                    digest: digest.to_string(),
                    got,
                });
            }
            self.put_blob(&buf)?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Bytes staged so far for an in-flight blob (the push protocol's
    /// progress echo).
    pub fn staged_len(&self, digest: &str) -> u64 {
        self.staging.get(digest).map(|s| s.buf.len() as u64).unwrap_or(0)
    }

    fn read_manifest(&self, digest: &str) -> Result<Manifest, StoreError> {
        let bytes = self.read_blob(digest)?;
        let text = String::from_utf8(bytes).map_err(|_| StoreError::BadManifest {
            detail: format!("manifest {digest} is not UTF-8"),
        })?;
        Manifest::parse(&text)
    }

    /// Publish an adapter's full Q/K/V/O stack: write its tensor blobs
    /// (dedup against existing ones), write the manifest document, index
    /// it, and persist the index. Returns the manifest digest.
    pub fn publish(
        &mut self,
        adapter: u64,
        rank: usize,
        base_model: &str,
        stack: &[AdapterWeights; 4],
    ) -> Result<String, StoreError> {
        let mut blobs = Vec::with_capacity(4);
        for (name, w) in TARGET_NAMES.iter().zip(stack.iter()) {
            let bytes = blob_bytes(w);
            let size = bytes.len() as u64;
            let digest = self.put_blob(&bytes)?;
            blobs.push(BlobDesc {
                target: (*name).to_string(),
                digest,
                size,
            });
        }
        let manifest = Manifest {
            adapter,
            rank,
            base_model: base_model.to_string(),
            blobs,
        };
        let text = manifest.canonical();
        let digest = self.put_blob(text.as_bytes())?;
        self.index.insert(adapter, digest.clone());
        self.manifests.insert(digest.clone(), manifest);
        self.save_index()?;
        Ok(digest)
    }

    /// Install a manifest document received over the wire: verify the
    /// text against its claimed digest, parse + validate it, require
    /// every referenced blob to be present and intact, then index it.
    /// Returns the adapter id it describes.
    pub fn publish_manifest(&mut self, text: &str, digest: &str) -> Result<u64, StoreError> {
        let got = hex_digest(text.as_bytes());
        if got != digest {
            return Err(StoreError::Corrupt {
                digest: digest.to_string(),
                got,
            });
        }
        let manifest = Manifest::parse(text)?;
        for b in &manifest.blobs {
            let bytes = self.read_blob(&b.digest)?;
            if bytes.len() as u64 != b.size {
                return Err(StoreError::SizeMismatch {
                    digest: b.digest.clone(),
                    expected: b.size,
                    got: bytes.len() as u64,
                });
            }
        }
        let adapter = manifest.adapter;
        self.put_blob(text.as_bytes())?;
        self.index.insert(adapter, digest.to_string());
        self.manifests.insert(digest.to_string(), manifest);
        self.save_index()?;
        Ok(adapter)
    }

    /// The indexed manifest (and its digest) for an adapter.
    pub fn manifest_of(&self, adapter: u64) -> Option<(&str, &Manifest)> {
        let digest = self.index.get(&adapter)?;
        let m = self.manifests.get(digest)?;
        Some((digest.as_str(), m))
    }

    /// The canonical manifest text for an adapter (what the wire ships).
    pub fn manifest_text(&self, adapter: u64) -> Result<(String, String), StoreError> {
        let (digest, m) = self
            .manifest_of(adapter)
            .ok_or(StoreError::NotFound { adapter })?;
        Ok((m.canonical(), digest.to_string()))
    }

    /// Load an adapter's Q/K/V/O stack, verifying every blob against its
    /// digest and the manifest's declared sizes. `hidden` must match the
    /// dimension the blob sizes imply (the consumer's model width).
    /// Success bumps [`Self::store_hits`].
    pub fn load_stack(
        &self,
        adapter: u64,
        hidden: usize,
    ) -> Result<(usize, [AdapterWeights; 4]), StoreError> {
        let (_, manifest) = self
            .manifest_of(adapter)
            .ok_or(StoreError::NotFound { adapter })?;
        let rank = manifest.rank;
        let implied = manifest.hidden()?;
        if implied != hidden {
            return Err(StoreError::BadManifest {
                detail: format!("manifest hidden {implied} != model hidden {hidden}"),
            });
        }
        let mut out: Vec<AdapterWeights> = Vec::with_capacity(4);
        for b in &manifest.blobs {
            let bytes = self.read_blob(&b.digest)?;
            if bytes.len() as u64 != b.size {
                return Err(StoreError::SizeMismatch {
                    digest: b.digest.clone(),
                    expected: b.size,
                    got: bytes.len() as u64,
                });
            }
            out.push(weights_from_blob(&bytes, hidden, rank)?);
        }
        let stack: [AdapterWeights; 4] = match out.try_into() {
            Ok(s) => s,
            Err(_) => {
                // Unreachable: parse() pins exactly 4 blobs.
                return Err(StoreError::BadManifest {
                    detail: "manifest does not hold 4 blobs".into(),
                });
            }
        };
        self.hits.fetch_add(1, Ordering::Relaxed); // ORDERING: independent counter, no ordering with other memory
        Ok((rank, stack))
    }

    /// Successful [`Self::load_stack`] calls — the store-hit counter the
    /// migration acceptance test reads over the wire.
    pub fn store_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed) // ORDERING: independent counter, no ordering with other memory
    }

    /// Drop an adapter from the index (its blobs stay until [`Self::gc`]).
    pub fn remove(&mut self, adapter: u64) -> Result<bool, StoreError> {
        let Some(digest) = self.index.remove(&adapter) else {
            return Ok(false);
        };
        // The manifest document stays cached only while some index entry
        // still points at it.
        if !self.index.values().any(|d| *d == digest) {
            self.manifests.remove(&digest);
        }
        self.save_index()?;
        Ok(true)
    }

    /// How many indexed manifests reference a blob (manifest documents
    /// count their index entries). 0 means [`Self::gc`] would collect it.
    pub fn refcount(&self, digest: &str) -> usize {
        let as_manifest = self.index.values().filter(|d| *d == digest).count();
        let as_tensor = self
            .index
            .values()
            .filter_map(|d| self.manifests.get(d))
            .flat_map(|m| m.blobs.iter())
            .filter(|b| b.digest == digest)
            .count();
        as_manifest + as_tensor
    }

    /// Delete every blob file no indexed manifest references. Returns
    /// the collected digests (sorted). Placed adapters are safe by
    /// construction: their manifests are in the index, so everything
    /// they reference is live.
    pub fn gc(&mut self) -> Result<Vec<String>, StoreError> {
        let mut live: BTreeSet<String> = self.index.values().cloned().collect();
        for digest in self.index.values() {
            if let Some(m) = self.manifests.get(digest) {
                for b in &m.blobs {
                    live.insert(b.digest.clone());
                }
            }
        }
        let mut collected = Vec::new();
        let dir = std::fs::read_dir(self.root.join("blobs")).map_err(|e| io_err("list blobs", e))?;
        for entry in dir {
            let entry = entry.map_err(|e| io_err("list blobs", e))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if !live.contains(&name) {
                std::fs::remove_file(entry.path()).map_err(|e| io_err("gc blob", e))?;
                collected.push(name);
            }
        }
        collected.sort();
        Ok(collected)
    }

    /// Verify every indexed manifest and every blob it references.
    /// Returns the number of blob files checked (manifests included).
    pub fn verify_all(&self) -> Result<usize, StoreError> {
        let mut checked = BTreeSet::new();
        for (adapter, digest) in &self.index {
            let manifest = self.read_manifest(digest)?;
            if manifest.adapter != *adapter {
                return Err(StoreError::BadManifest {
                    detail: format!(
                        "index entry {adapter} points at manifest for adapter {}",
                        manifest.adapter
                    ),
                });
            }
            checked.insert(digest.clone());
            for b in &manifest.blobs {
                let bytes = self.read_blob(&b.digest)?;
                if bytes.len() as u64 != b.size {
                    return Err(StoreError::SizeMismatch {
                        digest: b.digest.clone(),
                        expected: b.size,
                        got: bytes.len() as u64,
                    });
                }
                checked.insert(b.digest.clone());
            }
        }
        Ok(checked.len())
    }

    /// Indexed adapter ids, ascending.
    pub fn adapters(&self) -> Vec<u64> {
        self.index.keys().copied().collect()
    }

    /// Number of indexed adapters.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True when nothing is indexed.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Number of committed blob files on disk.
    pub fn blob_count(&self) -> Result<usize, StoreError> {
        let dir = std::fs::read_dir(self.root.join("blobs")).map_err(|e| io_err("list blobs", e))?;
        let mut n = 0;
        for entry in dir {
            entry.map_err(|e| io_err("list blobs", e))?;
            n += 1;
        }
        Ok(n)
    }

    /// Persist `index.json` (BTreeMap order → byte-stable re-saves,
    /// the `GlobalRegistry::save` discipline).
    fn save_index(&self) -> Result<(), StoreError> {
        let entries: Vec<Json> = self
            .index
            .iter()
            .map(|(id, digest)| {
                json::obj(vec![
                    ("id", json::num(*id as f64)),
                    ("manifest", json::s(digest)),
                ])
            })
            .collect();
        let doc = json::obj(vec![("adapters", Json::Arr(entries))]);
        std::fs::write(self.index_path(), doc.to_string_pretty())
            .map_err(|e| io_err("write index", e))
    }
}

/// The synthetic Q/K/V/O stack the engine seeds for an adapter when no
/// store manifest covers it — and the generator `caraserve artifacts
/// seed` publishes *into* a store. One definition keeps the two paths
/// bitwise-identical, which is what makes streams from transferred
/// weights indistinguishable from locally-seeded ones.
pub fn synthetic_stack(id: u64, hidden: usize, rank: usize) -> [AdapterWeights; 4] {
    std::array::from_fn(|t| AdapterWeights::synthetic(id * 31 + t as u64, hidden, hidden, rank))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("caraserve-artifacts-unit")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn publish_load_roundtrip_is_bitwise() {
        let root = tmp("roundtrip");
        let mut store = ArtifactStore::open(&root).unwrap();
        let stack = synthetic_stack(7, 32, 8);
        let digest = store.publish(7, 8, "tiny", &stack).unwrap();
        assert!(is_hex_digest(&digest));
        let (rank, back) = store.load_stack(7, 32).unwrap();
        assert_eq!(rank, 8);
        for (orig, re) in stack.iter().zip(back.iter()) {
            assert!(orig.a.iter().zip(&re.a).all(|(x, y)| x.to_bits() == y.to_bits()));
            assert!(orig.b.iter().zip(&re.b).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
        assert_eq!(store.store_hits(), 1);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn manifest_canonical_text_parses_back_and_digests_stably() {
        let root = tmp("manifest");
        let mut store = ArtifactStore::open(&root).unwrap();
        store.publish(3, 16, "tiny", &synthetic_stack(3, 16, 16)).unwrap();
        let (text, digest) = store.manifest_text(3).unwrap();
        assert_eq!(hex_digest(text.as_bytes()), digest);
        let m = Manifest::parse(&text).unwrap();
        assert_eq!(m.adapter, 3);
        assert_eq!(m.rank, 16);
        assert_eq!(m.digest(), digest);
        assert_eq!(m.hidden().unwrap(), 16);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn reopen_preserves_index_and_digests() {
        let root = tmp("reopen");
        let d1;
        {
            let mut store = ArtifactStore::open(&root).unwrap();
            d1 = store.publish(1, 8, "tiny", &synthetic_stack(1, 16, 8)).unwrap();
        }
        let store = ArtifactStore::open(&root).unwrap();
        assert_eq!(store.adapters(), vec![1]);
        assert_eq!(store.manifest_of(1).unwrap().0, d1);
        assert_eq!(store.verify_all().unwrap(), 5); // manifest + 4 tensors
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn shared_stack_stores_blobs_exactly_once() {
        let root = tmp("dedup");
        let mut store = ArtifactStore::open(&root).unwrap();
        let stack = synthetic_stack(5, 16, 8);
        store.publish(5, 8, "tiny", &stack).unwrap();
        let before = store.blob_count().unwrap();
        // A second adapter publishing the identical tensors adds only
        // its manifest document (different adapter id → different
        // manifest digest), never a second copy of a tensor blob.
        store.publish(6, 8, "tiny", &stack).unwrap();
        assert_eq!(store.blob_count().unwrap(), before + 1);
        for b in &store.manifest_of(5).unwrap().1.blobs.clone() {
            assert_eq!(store.refcount(&b.digest), 2);
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn gc_collects_only_unreferenced_blobs() {
        let root = tmp("gc");
        let mut store = ArtifactStore::open(&root).unwrap();
        store.publish(1, 8, "tiny", &synthetic_stack(1, 16, 8)).unwrap();
        store.publish(2, 8, "tiny", &synthetic_stack(2, 16, 8)).unwrap();
        assert!(store.gc().unwrap().is_empty()); // everything placed is live
        store.remove(2).unwrap();
        let collected = store.gc().unwrap();
        assert_eq!(collected.len(), 5); // adapter 2's manifest + 4 tensors
        // Adapter 1 survives intact.
        assert!(store.load_stack(1, 16).is_ok());
        assert_eq!(store.verify_all().unwrap(), 5);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn corrupt_blob_is_a_typed_rejection() {
        let root = tmp("corrupt");
        let mut store = ArtifactStore::open(&root).unwrap();
        store.publish(9, 8, "tiny", &synthetic_stack(9, 16, 8)).unwrap();
        let victim = store.manifest_of(9).unwrap().1.blobs[2].digest.clone();
        let path = store.blob_path(&victim);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        match store.load_stack(9, 16) {
            Err(StoreError::Corrupt { digest, .. }) => assert_eq!(digest, victim),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        assert_eq!(store.store_hits(), 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn ingest_chunks_commit_only_on_verified_completion() {
        let root = tmp("ingest");
        let mut store = ArtifactStore::open(&root).unwrap();
        let payload: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let digest = hex_digest(&payload);
        let total = payload.len() as u64;
        assert!(!store.ingest_chunk(&digest, 0, total, &payload[..400]).unwrap());
        assert_eq!(store.staged_len(&digest), 400);
        assert!(!store.has_blob(&digest));
        // Wrong offset: typed, and the stream resets.
        match store.ingest_chunk(&digest, 900, total, &payload[900..]) {
            Err(StoreError::ChunkOutOfOrder { expected, got, .. }) => {
                assert_eq!((expected, got), (400, 900));
            }
            other => panic!("expected ChunkOutOfOrder, got {other:?}"),
        }
        assert_eq!(store.staged_len(&digest), 0);
        // Clean sequential retry commits and verifies.
        assert!(!store.ingest_chunk(&digest, 0, total, &payload[..512]).unwrap());
        assert!(store.ingest_chunk(&digest, 512, total, &payload[512..]).unwrap());
        assert_eq!(store.read_blob(&digest).unwrap(), payload);
        // A stream whose bytes don't hash to the address is refused.
        let mut wrong = payload.clone();
        wrong[0] ^= 1;
        let bad = hex_digest(&payload[..1]); // valid hex, wrong content
        match store.ingest_chunk(&bad, 0, wrong.len() as u64, &wrong) {
            Err(StoreError::Corrupt { .. }) => {}
            other => panic!("expected Corrupt, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn index_resaves_are_byte_stable() {
        let root = tmp("stable");
        let mut store = ArtifactStore::open(&root).unwrap();
        store.publish(2, 8, "tiny", &synthetic_stack(2, 16, 8)).unwrap();
        store.publish(1, 16, "tiny", &synthetic_stack(1, 16, 16)).unwrap();
        let first = std::fs::read_to_string(root.join("index.json")).unwrap();
        let mut store2 = ArtifactStore::open(&root).unwrap();
        store2.save_index().unwrap();
        let second = std::fs::read_to_string(root.join("index.json")).unwrap();
        assert_eq!(first, second);
        let _ = std::fs::remove_dir_all(&root);
    }
}
