//! Baseline scheduling policies (paper §7.5): MostIdle, FirstFit
//! (Punica's strategy), and Random. All judge per-request eligibility
//! through [`ServerStats::eligible_for`] (adapter hosted + KV headroom).

use super::{Policy, SchedRequest, ServerStats};
use crate::perfmodel::PerfModel;
use crate::util::rng::Rng;

/// Route to the eligible server with the least total requests.
pub struct MostIdle;

impl Policy for MostIdle {
    fn pick(&mut self, req: &SchedRequest, stats: &[ServerStats]) -> Option<usize> {
        stats
            .iter()
            .enumerate()
            .filter(|(_, s)| s.eligible_for(req))
            .min_by_key(|(_, s)| s.total_requests())
            .map(|(i, _)| i)
    }

    fn name(&self) -> &'static str {
        "most-idle"
    }
}

/// First-fit bin packing (Punica): scan servers in fixed order, take the
/// first whose predicted decode latency stays within a capacity bound.
/// Falls back to the last eligible server when none "fits".
pub struct FirstFit {
    dec_perf: PerfModel,
    capacity: f64,
}

impl FirstFit {
    /// `capacity` is the decode-latency bound treated as bin capacity.
    pub fn new(dec_perf: PerfModel, capacity: f64) -> Self {
        FirstFit { dec_perf, capacity }
    }
}

impl Policy for FirstFit {
    fn pick(&mut self, req: &SchedRequest, stats: &[ServerStats]) -> Option<usize> {
        let mut last_eligible = None;
        for (i, s) in stats.iter().enumerate() {
            if !s.eligible_for(req) {
                continue;
            }
            last_eligible = Some(i);
            let mut ranks: Vec<usize> = s.running_ranks.clone();
            ranks.extend(&s.queued_ranks);
            ranks.push(req.rank);
            if self.dec_perf.predict(&ranks) <= self.capacity {
                return Some(i);
            }
        }
        last_eligible
    }

    fn name(&self) -> &'static str {
        "first-fit"
    }
}

/// Uniformly random among eligible servers.
pub struct RandomPick {
    rng: Rng,
}

impl RandomPick {
    /// Seeded for reproducibility.
    pub fn new(rng: Rng) -> Self {
        RandomPick { rng }
    }
}

impl Policy for RandomPick {
    fn pick(&mut self, req: &SchedRequest, stats: &[ServerStats]) -> Option<usize> {
        let eligible: Vec<usize> = stats
            .iter()
            .enumerate()
            .filter(|(_, s)| s.eligible_for(req))
            .map(|(i, _)| i)
            .collect();
        if eligible.is_empty() {
            None
        } else {
            Some(eligible[self.rng.range(0, eligible.len())])
        }
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::KernelKind;
    use crate::scheduler::AdapterSet;

    fn stats(loads: &[usize]) -> Vec<ServerStats> {
        loads
            .iter()
            .map(|&n| ServerStats {
                running_ranks: vec![32; n],
                ..Default::default()
            })
            .collect()
    }

    fn req() -> SchedRequest {
        SchedRequest {
            id: 1,
            adapter: 1,
            rank: 32,
            prompt_len: 16,
        }
    }

    #[test]
    fn most_idle_picks_least_loaded() {
        let mut p = MostIdle;
        assert_eq!(p.pick(&req(), &stats(&[5, 2, 9])), Some(1));
    }

    #[test]
    fn most_idle_skips_servers_without_the_adapter() {
        let mut p = MostIdle;
        let mut s = stats(&[5, 2, 9]);
        s[1].adapters = AdapterSet::only(vec![7]);
        assert_eq!(p.pick(&req(), &s), Some(0));
    }

    #[test]
    fn most_idle_skips_servers_that_cannot_hold_the_prompt() {
        let mut p = MostIdle;
        let mut s = stats(&[5, 2, 9]);
        s[1].max_prompt_tokens = 8; // prompt is 16
        assert_eq!(p.pick(&req(), &s), Some(0));
    }

    #[test]
    fn first_fit_takes_first_that_fits() {
        let dec = PerfModel::from_coefficients(KernelKind::Bgmv, 1.3e-5, 24.8e-3);
        let mut p = FirstFit::new(dec, 36e-3);
        // Server 0: 24×32 + req → 25·32·1.3e-5+24.8e-3 = 35.2ms ≤ 36ms: fits.
        assert_eq!(p.pick(&req(), &stats(&[24, 0])), Some(0));
    }

    #[test]
    fn first_fit_overflows_to_next_and_falls_back() {
        let dec = PerfModel::from_coefficients(KernelKind::Bgmv, 1.3e-5, 24.8e-3);
        let mut p = FirstFit::new(dec, 36e-3);
        // Server 0 full (40×32 → >36ms), server 1 empty: pick 1.
        assert_eq!(p.pick(&req(), &stats(&[40, 0])), Some(1));
        // All full: fall back to the last eligible.
        assert_eq!(p.pick(&req(), &stats(&[40, 40])), Some(1));
    }

    #[test]
    fn random_is_uniform_ish_and_respects_eligibility() {
        let mut p = RandomPick::new(Rng::new(7));
        let mut s = stats(&[1, 1, 1]);
        s[2].adapters = AdapterSet::only(vec![]);
        let mut counts = [0usize; 3];
        for _ in 0..1000 {
            counts[p.pick(&req(), &s).unwrap()] += 1;
        }
        assert_eq!(counts[2], 0);
        assert!(counts[0] > 300 && counts[1] > 300, "{counts:?}");
    }

    #[test]
    fn all_policies_none_on_empty() {
        let dec = PerfModel::from_coefficients(KernelKind::Bgmv, 1e-5, 0.03);
        assert!(MostIdle.pick(&req(), &[]).is_none());
        assert!(FirstFit::new(dec, 0.036).pick(&req(), &[]).is_none());
        assert!(RandomPick::new(Rng::new(1)).pick(&req(), &[]).is_none());
    }
}
