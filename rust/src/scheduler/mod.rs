//! Cluster-level request scheduling (paper §5, Algorithm 1).
//!
//! The scheduler receives every user request, gathers each candidate
//! server's running-batch/queue state, scores the *additional* latency
//! cost the new request would impose via the per-kernel performance
//! models, adds an SLO-violation penalty, and routes to the minimum-cost
//! server. Baselines from §7.5 (MostIdle, FirstFit, Random) live in
//! [`baselines`]; the global adapter-metadata store in [`registry`].
//!
//! Eligibility is judged per request, not per server: every
//! [`ServerStats`] snapshot carries the server's loadable adapter set
//! ([`AdapterSet`]) and its free KV headroom, and policies call
//! [`ServerStats::eligible_for`] — a server that does not host the
//! request's adapter, or cannot hold its prompt, is skipped. Both real
//! engines ([`crate::server::InferenceServer`]) and the simulator
//! produce these fields for real; the cluster front
//! ([`crate::server::ClusterFront`]) routes against them.

pub mod baselines;
pub mod registry;

use crate::perfmodel::PerfModel;
use crate::util::rng::Rng;

/// A request as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct SchedRequest {
    pub id: u64,
    /// LoRA adapter id.
    pub adapter: u64,
    /// Adapter rank (from the global registry).
    pub rank: usize,
    /// Prompt length in tokens.
    pub prompt_len: usize,
}

/// The set of adapters a server can serve — resident or loadable from
/// its local repository. Replaces the old hardcoded `eligible: bool`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub enum AdapterSet {
    /// Any registered adapter (simulated instances model no repository).
    #[default]
    Any,
    /// Exactly these adapter ids (sorted, deduplicated — build with
    /// [`AdapterSet::only`]). An empty set means the server serves
    /// nothing, e.g. a drained or routing-excluded backend.
    Only(Vec<u64>),
}

impl AdapterSet {
    /// A set of exactly `ids` (sorted + deduplicated here so
    /// [`AdapterSet::contains`] can binary-search).
    pub fn only(mut ids: Vec<u64>) -> AdapterSet {
        ids.sort_unstable();
        ids.dedup();
        AdapterSet::Only(ids)
    }

    /// Can this set serve `adapter`?
    pub fn contains(&self, adapter: u64) -> bool {
        match self {
            AdapterSet::Any => true,
            AdapterSet::Only(ids) => ids.binary_search(&adapter).is_ok(),
        }
    }

    /// The union of two sets (the cluster front's aggregate view).
    pub fn union(&self, other: &AdapterSet) -> AdapterSet {
        match (self, other) {
            (AdapterSet::Any, _) | (_, AdapterSet::Any) => AdapterSet::Any,
            (AdapterSet::Only(a), AdapterSet::Only(b)) => {
                let mut ids = a.clone();
                ids.extend(b);
                AdapterSet::only(ids)
            }
        }
    }
}

/// A snapshot of one inference server's load (what `GetStats` returns in
/// Algorithm 1). Produced uniformly by every [`ServingFront`] backend
/// (`ServingFront::stats`), real engine and simulator alike.
///
/// [`ServingFront`]: crate::server::ServingFront
#[derive(Debug, Clone, PartialEq)]
pub struct ServerStats {
    /// Ranks of requests currently in the running (decoding) batch.
    pub running_ranks: Vec<usize>,
    /// Ranks of requests queued for prefill.
    pub queued_ranks: Vec<usize>,
    /// Adapters this server hosts in its local repository (resident or
    /// loadable). Policies must not route a request whose adapter is
    /// outside this set.
    pub adapters: AdapterSet,
    /// Hard admission bound: the longest prompt this server can ever
    /// accept (prefill bucket bound, capped by total KV pool capacity);
    /// `usize::MAX` when unmodeled. Gates [`ServerStats::eligible_for`].
    pub max_prompt_tokens: usize,
    /// Instantaneous free KV headroom in tokens (free pages × page size
    /// on the engine); `usize::MAX` when the backend does not model a
    /// bounded pool. A soft pressure signal — pages free again as
    /// requests complete, so this does not gate eligibility.
    pub kv_free_tokens: usize,
    /// Tightest per-output-token SLO (seconds) among the server's live
    /// requests, if any carries one. The scheduler compares its
    /// predicted decode latency against this instead of the global
    /// default, so routing respects the thinnest headroom on board.
    pub tpot_slo: Option<f64>,
    /// Decode-growth preemptions this server has performed (requests
    /// evicted mid-decode because the KV pool ran dry). A load-shedding
    /// signal: the rank-aware policy penalizes servers that preempt.
    pub preemptions: usize,
    /// Total pages in the unified device pool; 0 when the backend does
    /// not model one (simulated instances). With the per-class counters
    /// below this turns slot pressure into a real memory-pressure score
    /// for `coordinator::placement`.
    pub pool_pages: usize,
    /// Unified-pool pages currently held by request KV.
    pub kv_held_pages: usize,
    /// Unified-pool pages currently held by resident adapter weights.
    /// `kv_free_tokens` already nets these out — the two budgets compete
    /// for the same free list.
    pub adapter_held_pages: usize,
    /// Idle-adapter pressure evictions this server has performed (weight
    /// pages reclaimed to admit KV or a different adapter). Like
    /// `preemptions`, a monotone churn signal.
    pub adapter_evictions: usize,
    /// `Token` events coalesced away by bounded per-request event
    /// buffers (see `server::api::EventChannel`): each one a consumer
    /// that fell behind its stream. Token *values* are never lost —
    /// only event granularity — so this is a consumer-health signal,
    /// not a correctness one.
    pub event_overflows: usize,
}

impl Default for ServerStats {
    fn default() -> Self {
        ServerStats {
            running_ranks: Vec::new(),
            queued_ranks: Vec::new(),
            adapters: AdapterSet::Any,
            max_prompt_tokens: usize::MAX,
            kv_free_tokens: usize::MAX,
            tpot_slo: None,
            preemptions: 0,
            pool_pages: 0,
            kv_held_pages: 0,
            adapter_held_pages: 0,
            adapter_evictions: 0,
            event_overflows: 0,
        }
    }
}

impl ServerStats {
    /// Total requests on the server (running + queued).
    pub fn total_requests(&self) -> usize {
        self.running_ranks.len() + self.queued_ranks.len()
    }

    /// Does this server host `adapter` (resident or loadable)?
    pub fn can_serve(&self, adapter: u64) -> bool {
        self.adapters.contains(adapter)
    }

    /// Algorithm 1's eligibility check, computed for real: the server
    /// hosts the request's adapter *and* can ever hold its prompt.
    pub fn eligible_for(&self, req: &SchedRequest) -> bool {
        self.can_serve(req.adapter) && self.max_prompt_tokens >= req.prompt_len
    }
}

/// A scheduling policy: choose a server index for a request.
pub trait Policy {
    /// Pick among `stats` (one entry per server); `None` if no server is
    /// eligible for this request ([`ServerStats::eligible_for`]).
    fn pick(&mut self, req: &SchedRequest, stats: &[ServerStats]) -> Option<usize>;

    /// Policy name for reports.
    fn name(&self) -> &'static str;
}

/// Configuration for the rank-aware policy.
#[derive(Debug, Clone)]
pub struct RankAwareConfig {
    /// Time-per-token SLO (seconds) on decode latency.
    pub slo: f64,
    /// Penalty added to the cost score on predicted SLO violation.
    pub penalty: f64,
    /// Average response length (tokens) used to amortize prefill cost.
    pub avg_resp_len: f64,
}

impl Default for RankAwareConfig {
    fn default() -> Self {
        RankAwareConfig {
            slo: 36e-3,
            penalty: 1.0,
            avg_resp_len: 60.0,
        }
    }
}

/// Algorithm 1: rank-aware scheduling with performance-model cost scores.
pub struct RankAwareScheduler {
    /// Prefill-latency model (per iteration).
    pub pre_perf: PerfModel,
    /// Decode-latency model (per iteration).
    pub dec_perf: PerfModel,
    pub cfg: RankAwareConfig,
}

impl RankAwareScheduler {
    /// Build from fitted models and config.
    pub fn new(pre_perf: PerfModel, dec_perf: PerfModel, cfg: RankAwareConfig) -> Self {
        RankAwareScheduler {
            pre_perf,
            dec_perf,
            cfg,
        }
    }

    /// `CalcCost` (Algorithm 1, lines 13–23): the marginal latency the
    /// new request inflicts on a server with the given state.
    ///
    /// Allocation-free: features are computed over chained iterators
    /// instead of concatenated vectors — this runs once per (arrival ×
    /// server) and dominated the 60-instance routing loop before the
    /// rewrite (EXPERIMENTS.md §Perf).
    pub fn calc_cost(&self, req: &SchedRequest, stats: &ServerStats) -> f64 {
        let run = stats.running_ranks.iter().copied();
        let q = stats.queued_ranks.iter().copied();
        let one = std::iter::once(req.rank);

        // Δ_prefill = PrePerf(queue + req) − PrePerf(queue)
        let d_prefill = self.pre_perf.predict_iter(q.clone().chain(one.clone()))
            - self.pre_perf.predict_iter(q.clone());

        // Δ_decode = DecPerf(exists + req) − DecPerf(exists), where
        // exists = running_batch + queue.
        let dec_plus = self
            .dec_perf
            .predict_iter(run.clone().chain(q.clone()).chain(one));
        let d_decode = dec_plus - self.dec_perf.predict_iter(run.chain(q));

        let mut cost = d_prefill / self.cfg.avg_resp_len + d_decode;
        // SLO headroom: judge against the tightest per-token SLO the
        // server's live requests carry, when stricter than the default.
        let slo = stats
            .tpot_slo
            .map_or(self.cfg.slo, |s| s.min(self.cfg.slo));
        if dec_plus > slo {
            cost += self.cfg.penalty;
        }
        // Load-shedding steering: a server that has preempted running
        // requests (KV pool ran dry mid-decode) is memory-pressured in a
        // way running_ranks alone doesn't show — bias routing away. The
        // bias is in marginal-cost units (each past preemption counts
        // like one extra resident request), not penalty units: the
        // counter never decays, so a penalty-scale term would let one
        // historical preemption dominate the score forever and herd all
        // traffic onto the other servers.
        cost += d_decode.max(0.0) * stats.preemptions as f64;
        cost
    }
}

impl Policy for RankAwareScheduler {
    fn pick(&mut self, req: &SchedRequest, stats: &[ServerStats]) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, s) in stats.iter().enumerate() {
            if !s.eligible_for(req) {
                continue;
            }
            // total_cost = cost · requests (Algorithm 1 line 8 weights the
            // marginal cost by how many requests it disturbs).
            let cost = self.calc_cost(req, s);
            let total = cost * (s.total_requests() + 1) as f64;
            match best {
                None => best = Some((i, total)),
                Some((_, b)) if total < b => best = Some((i, total)),
                _ => {}
            }
        }
        best.map(|(i, _)| i)
    }

    fn name(&self) -> &'static str {
        "rank-aware"
    }
}

/// Construct a policy by name ("rank-aware", "most-idle", "first-fit",
/// "random") with the given models/config/seed. Unknown names are an
/// error, not a panic — CLI surfaces report them to the user.
pub fn policy_by_name(
    name: &str,
    pre: PerfModel,
    dec: PerfModel,
    cfg: RankAwareConfig,
    seed: u64,
) -> anyhow::Result<Box<dyn Policy>> {
    match name {
        "rank-aware" => Ok(Box::new(RankAwareScheduler::new(pre, dec, cfg))),
        "most-idle" => Ok(Box::new(baselines::MostIdle)),
        "first-fit" => Ok(Box::new(baselines::FirstFit::new(dec, cfg.slo))),
        "random" => Ok(Box::new(baselines::RandomPick::new(Rng::new(seed)))),
        other => anyhow::bail!(
            "unknown policy {other} (expected rank-aware|most-idle|first-fit|random)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::KernelKind;

    fn models_bgmv() -> (PerfModel, PerfModel) {
        // Calibrated to the Fig 5 toy example (see perfmodel tests).
        let dec = PerfModel::from_coefficients(KernelKind::Bgmv, 1.3e-5, 24.8e-3);
        let pre = PerfModel::from_coefficients(KernelKind::Bgmv, 4e-5, 60e-3);
        (pre, dec)
    }

    fn models_mbgmv() -> (PerfModel, PerfModel) {
        let dec = PerfModel::from_coefficients(KernelKind::Mbgmv, 1.05e-5, 25.1e-3);
        let pre = PerfModel::from_coefficients(KernelKind::Mbgmv, 3e-5, 60e-3);
        (pre, dec)
    }

    fn fig5_stats() -> Vec<ServerStats> {
        vec![
            ServerStats {
                running_ranks: vec![32; 24],
                ..Default::default()
            },
            ServerStats {
                running_ranks: vec![64; 16],
                ..Default::default()
            },
        ]
    }

    #[test]
    fn fig5_bgmv_routes_to_instance2() {
        // Paper Fig 5: with BGMV, a new rank-64 request must go to
        // Instance 2 (Instance 1 would jump to max-rank 64 for 25 reqs).
        let (pre, dec) = models_bgmv();
        let mut sched = RankAwareScheduler::new(
            pre,
            dec,
            RankAwareConfig {
                slo: 36e-3,
                ..Default::default()
            },
        );
        let req = SchedRequest {
            id: 1,
            adapter: 9,
            rank: 64,
            prompt_len: 32,
        };
        assert_eq!(sched.pick(&req, &fig5_stats()), Some(1));
    }

    #[test]
    fn fig5_mbgmv_routes_to_instance1() {
        // With MBGMV the cost tracks Σrank: Instance 2 already has the
        // higher rank-sum, so the request goes to Instance 1.
        let (pre, dec) = models_mbgmv();
        let mut sched = RankAwareScheduler::new(
            pre,
            dec,
            RankAwareConfig {
                slo: 36e-3,
                ..Default::default()
            },
        );
        let req = SchedRequest {
            id: 1,
            adapter: 9,
            rank: 64,
            prompt_len: 32,
        };
        assert_eq!(sched.pick(&req, &fig5_stats()), Some(0));
    }

    #[test]
    fn adapter_set_eligibility_skips_servers() {
        let (pre, dec) = models_bgmv();
        let mut sched = RankAwareScheduler::new(pre, dec, RankAwareConfig::default());
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 8,
            prompt_len: 16,
        };
        let mut stats = fig5_stats();
        // Server 1 hosts other adapters only — ineligible for adapter 1.
        stats[1].adapters = AdapterSet::only(vec![7, 9]);
        assert_eq!(sched.pick(&req, &stats), Some(0));
        // Server 0 drained (empty set): no eligible server remains.
        stats[0].adapters = AdapterSet::only(vec![]);
        assert_eq!(sched.pick(&req, &stats), None);
    }

    #[test]
    fn kv_headroom_gates_eligibility() {
        let (pre, dec) = models_bgmv();
        let mut sched = RankAwareScheduler::new(pre, dec, RankAwareConfig::default());
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 8,
            prompt_len: 64,
        };
        let mut stats = fig5_stats();
        // The otherwise-cheaper server can never hold the prompt.
        stats[1].max_prompt_tokens = 32;
        assert!(!stats[1].eligible_for(&req));
        assert_eq!(sched.pick(&req, &stats), Some(0));
        stats[0].max_prompt_tokens = 63;
        assert_eq!(sched.pick(&req, &stats), None);
    }

    #[test]
    fn slo_penalty_applied() {
        let (pre, dec) = models_bgmv();
        let sched = RankAwareScheduler::new(
            pre,
            dec,
            RankAwareConfig {
                slo: 36e-3,
                penalty: 100.0,
                avg_resp_len: 60.0,
            },
        );
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 64,
            prompt_len: 16,
        };
        // 24×r32 + new r64 violates (25·64 feature → ~45.6ms > 36ms).
        let crowded = ServerStats {
            running_ranks: vec![32; 24],
            ..Default::default()
        };
        let idle = ServerStats::default();
        assert!(sched.calc_cost(&req, &crowded) > 100.0);
        assert!(sched.calc_cost(&req, &idle) < 1.0);
    }

    #[test]
    fn tighter_onboard_slo_triggers_penalty_earlier() {
        let (pre, dec) = models_bgmv();
        let sched = RankAwareScheduler::new(
            pre,
            dec,
            RankAwareConfig {
                slo: 36e-3,
                penalty: 100.0,
                avg_resp_len: 60.0,
            },
        );
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 32,
            prompt_len: 16,
        };
        // A lightly loaded server: within the 36 ms default SLO…
        let mut stats = ServerStats {
            running_ranks: vec![32; 8],
            ..Default::default()
        };
        assert!(sched.calc_cost(&req, &stats) < 1.0);
        // …but a resident request carrying a 25 ms SLO flips the penalty.
        stats.tpot_slo = Some(25e-3);
        assert!(sched.calc_cost(&req, &stats) > 100.0);
    }

    #[test]
    fn preemptions_steer_routing_away() {
        let (pre, dec) = models_bgmv();
        let mut sched = RankAwareScheduler::new(
            pre,
            dec,
            RankAwareConfig {
                penalty: 10.0,
                ..Default::default()
            },
        );
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 32,
            prompt_len: 16,
        };
        // Server 1 is emptier but has shed load by preempting: avoid it.
        let stats = vec![
            ServerStats {
                running_ranks: vec![32; 4],
                ..Default::default()
            },
            ServerStats {
                running_ranks: vec![32; 2],
                preemptions: 3,
                ..Default::default()
            },
        ];
        assert_eq!(sched.pick(&req, &stats), Some(0));
    }

    #[test]
    fn empty_cluster_returns_none() {
        let (pre, dec) = models_bgmv();
        let mut sched = RankAwareScheduler::new(pre, dec, RankAwareConfig::default());
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 8,
            prompt_len: 16,
        };
        assert_eq!(sched.pick(&req, &[]), None);
    }

    #[test]
    fn prefers_emptier_server_all_else_equal() {
        let (pre, dec) = models_bgmv();
        let mut sched = RankAwareScheduler::new(pre, dec, RankAwareConfig::default());
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 32,
            prompt_len: 16,
        };
        let stats = vec![
            ServerStats {
                running_ranks: vec![32; 10],
                ..Default::default()
            },
            ServerStats {
                running_ranks: vec![32; 2],
                ..Default::default()
            },
        ];
        assert_eq!(sched.pick(&req, &stats), Some(1));
    }

    #[test]
    fn adapter_set_contains_and_union() {
        let a = AdapterSet::only(vec![3, 1, 3]);
        assert!(a.contains(1) && a.contains(3) && !a.contains(2));
        assert!(AdapterSet::Any.contains(42));
        assert_eq!(a.union(&AdapterSet::Any), AdapterSet::Any);
        let b = AdapterSet::only(vec![2, 3]);
        assert_eq!(a.union(&b), AdapterSet::only(vec![1, 2, 3]));
    }

    #[test]
    fn policy_by_name_errors_on_unknown() {
        let (pre, dec) = models_bgmv();
        let err = policy_by_name("banana", pre.clone(), dec.clone(), RankAwareConfig::default(), 1)
            .err()
            .expect("unknown policy must error");
        assert!(err.to_string().contains("banana"), "{err}");
        for name in ["rank-aware", "most-idle", "first-fit", "random"] {
            let p = policy_by_name(name, pre.clone(), dec.clone(), RankAwareConfig::default(), 1)
                .expect("known policy");
            assert_eq!(p.name(), name);
        }
    }
}
