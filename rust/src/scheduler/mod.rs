//! Cluster-level request scheduling (paper §5, Algorithm 1).
//!
//! The scheduler receives every user request, gathers each candidate
//! server's running-batch/queue state, scores the *additional* latency
//! cost the new request would impose via the per-kernel performance
//! models, adds an SLO-violation penalty, and routes to the minimum-cost
//! server. Baselines from §7.5 (MostIdle, FirstFit, Random) live in
//! [`baselines`]; the global adapter-metadata store in [`registry`].

pub mod baselines;
pub mod registry;

use crate::perfmodel::PerfModel;
use crate::util::rng::Rng;

/// A request as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct SchedRequest {
    pub id: u64,
    /// LoRA adapter id.
    pub adapter: u64,
    /// Adapter rank (from the global registry).
    pub rank: usize,
    /// Prompt length in tokens.
    pub prompt_len: usize,
}

/// A snapshot of one inference server's load (what `GetStats` returns in
/// Algorithm 1). Produced uniformly by every [`ServingFront`] backend
/// (`ServingFront::stats`), real engine and simulator alike.
///
/// [`ServingFront`]: crate::server::ServingFront
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    /// Ranks of requests currently in the running (decoding) batch.
    pub running_ranks: Vec<usize>,
    /// Ranks of requests queued for prefill.
    pub queued_ranks: Vec<usize>,
    /// True if the server hosts this request's base model + adapter and
    /// has GPU memory headroom.
    pub eligible: bool,
    /// Tightest per-output-token SLO (seconds) among the server's live
    /// requests, if any carries one. The scheduler compares its
    /// predicted decode latency against this instead of the global
    /// default, so routing respects the thinnest headroom on board.
    pub tpot_slo: Option<f64>,
}

impl ServerStats {
    /// Total requests on the server (running + queued).
    pub fn total_requests(&self) -> usize {
        self.running_ranks.len() + self.queued_ranks.len()
    }
}

/// A scheduling policy: choose a server index for a request.
pub trait Policy {
    /// Pick among `stats` (one entry per server); `None` if no server is
    /// eligible.
    fn pick(&mut self, req: &SchedRequest, stats: &[ServerStats]) -> Option<usize>;

    /// Policy name for reports.
    fn name(&self) -> &'static str;
}

/// Configuration for the rank-aware policy.
#[derive(Debug, Clone)]
pub struct RankAwareConfig {
    /// Time-per-token SLO (seconds) on decode latency.
    pub slo: f64,
    /// Penalty added to the cost score on predicted SLO violation.
    pub penalty: f64,
    /// Average response length (tokens) used to amortize prefill cost.
    pub avg_resp_len: f64,
}

impl Default for RankAwareConfig {
    fn default() -> Self {
        RankAwareConfig {
            slo: 36e-3,
            penalty: 1.0,
            avg_resp_len: 60.0,
        }
    }
}

/// Algorithm 1: rank-aware scheduling with performance-model cost scores.
pub struct RankAwareScheduler {
    /// Prefill-latency model (per iteration).
    pub pre_perf: PerfModel,
    /// Decode-latency model (per iteration).
    pub dec_perf: PerfModel,
    pub cfg: RankAwareConfig,
}

impl RankAwareScheduler {
    /// Build from fitted models and config.
    pub fn new(pre_perf: PerfModel, dec_perf: PerfModel, cfg: RankAwareConfig) -> Self {
        RankAwareScheduler {
            pre_perf,
            dec_perf,
            cfg,
        }
    }

    /// `CalcCost` (Algorithm 1, lines 13–23): the marginal latency the
    /// new request inflicts on a server with the given state.
    ///
    /// Allocation-free: features are computed over chained iterators
    /// instead of concatenated vectors — this runs once per (arrival ×
    /// server) and dominated the 60-instance routing loop before the
    /// rewrite (EXPERIMENTS.md §Perf).
    pub fn calc_cost(&self, req: &SchedRequest, stats: &ServerStats) -> f64 {
        let run = stats.running_ranks.iter().copied();
        let q = stats.queued_ranks.iter().copied();
        let one = std::iter::once(req.rank);

        // Δ_prefill = PrePerf(queue + req) − PrePerf(queue)
        let d_prefill = self.pre_perf.predict_iter(q.clone().chain(one.clone()))
            - self.pre_perf.predict_iter(q.clone());

        // Δ_decode = DecPerf(exists + req) − DecPerf(exists), where
        // exists = running_batch + queue.
        let dec_plus = self
            .dec_perf
            .predict_iter(run.clone().chain(q.clone()).chain(one));
        let d_decode = dec_plus - self.dec_perf.predict_iter(run.chain(q));

        let mut cost = d_prefill / self.cfg.avg_resp_len + d_decode;
        // SLO headroom: judge against the tightest per-token SLO the
        // server's live requests carry, when stricter than the default.
        let slo = stats
            .tpot_slo
            .map_or(self.cfg.slo, |s| s.min(self.cfg.slo));
        if dec_plus > slo {
            cost += self.cfg.penalty;
        }
        cost
    }
}

impl Policy for RankAwareScheduler {
    fn pick(&mut self, req: &SchedRequest, stats: &[ServerStats]) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, s) in stats.iter().enumerate() {
            if !s.eligible {
                continue;
            }
            // total_cost = cost · requests (Algorithm 1 line 8 weights the
            // marginal cost by how many requests it disturbs).
            let cost = self.calc_cost(req, s);
            let total = cost * (s.total_requests() + 1) as f64;
            match best {
                None => best = Some((i, total)),
                Some((_, b)) if total < b => best = Some((i, total)),
                _ => {}
            }
        }
        best.map(|(i, _)| i)
    }

    fn name(&self) -> &'static str {
        "rank-aware"
    }
}

/// Construct a policy by name ("rank-aware", "most-idle", "first-fit",
/// "random") with the given models/config/seed.
pub fn policy_by_name(
    name: &str,
    pre: PerfModel,
    dec: PerfModel,
    cfg: RankAwareConfig,
    seed: u64,
) -> Box<dyn Policy> {
    match name {
        "rank-aware" => Box::new(RankAwareScheduler::new(pre, dec, cfg)),
        "most-idle" => Box::new(baselines::MostIdle),
        "first-fit" => Box::new(baselines::FirstFit::new(dec, cfg.slo)),
        "random" => Box::new(baselines::RandomPick::new(Rng::new(seed))),
        other => panic!("unknown policy {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::KernelKind;

    fn models_bgmv() -> (PerfModel, PerfModel) {
        // Calibrated to the Fig 5 toy example (see perfmodel tests).
        let dec = PerfModel::from_coefficients(KernelKind::Bgmv, 1.3e-5, 24.8e-3);
        let pre = PerfModel::from_coefficients(KernelKind::Bgmv, 4e-5, 60e-3);
        (pre, dec)
    }

    fn models_mbgmv() -> (PerfModel, PerfModel) {
        let dec = PerfModel::from_coefficients(KernelKind::Mbgmv, 1.05e-5, 25.1e-3);
        let pre = PerfModel::from_coefficients(KernelKind::Mbgmv, 3e-5, 60e-3);
        (pre, dec)
    }

    fn fig5_stats() -> Vec<ServerStats> {
        vec![
            ServerStats {
                running_ranks: vec![32; 24],
                queued_ranks: vec![],
                eligible: true,
                tpot_slo: None,
            },
            ServerStats {
                running_ranks: vec![64; 16],
                queued_ranks: vec![],
                eligible: true,
                tpot_slo: None,
            },
        ]
    }

    #[test]
    fn fig5_bgmv_routes_to_instance2() {
        // Paper Fig 5: with BGMV, a new rank-64 request must go to
        // Instance 2 (Instance 1 would jump to max-rank 64 for 25 reqs).
        let (pre, dec) = models_bgmv();
        let mut sched = RankAwareScheduler::new(
            pre,
            dec,
            RankAwareConfig {
                slo: 36e-3,
                ..Default::default()
            },
        );
        let req = SchedRequest {
            id: 1,
            adapter: 9,
            rank: 64,
            prompt_len: 32,
        };
        assert_eq!(sched.pick(&req, &fig5_stats()), Some(1));
    }

    #[test]
    fn fig5_mbgmv_routes_to_instance1() {
        // With MBGMV the cost tracks Σrank: Instance 2 already has the
        // higher rank-sum, so the request goes to Instance 1.
        let (pre, dec) = models_mbgmv();
        let mut sched = RankAwareScheduler::new(
            pre,
            dec,
            RankAwareConfig {
                slo: 36e-3,
                ..Default::default()
            },
        );
        let req = SchedRequest {
            id: 1,
            adapter: 9,
            rank: 64,
            prompt_len: 32,
        };
        assert_eq!(sched.pick(&req, &fig5_stats()), Some(0));
    }

    #[test]
    fn ineligible_servers_skipped() {
        let (pre, dec) = models_bgmv();
        let mut sched = RankAwareScheduler::new(pre, dec, RankAwareConfig::default());
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 8,
            prompt_len: 16,
        };
        let mut stats = fig5_stats();
        stats[1].eligible = false;
        assert_eq!(sched.pick(&req, &stats), Some(0));
        stats[0].eligible = false;
        assert_eq!(sched.pick(&req, &stats), None);
    }

    #[test]
    fn slo_penalty_applied() {
        let (pre, dec) = models_bgmv();
        let sched = RankAwareScheduler::new(
            pre,
            dec,
            RankAwareConfig {
                slo: 36e-3,
                penalty: 100.0,
                avg_resp_len: 60.0,
            },
        );
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 64,
            prompt_len: 16,
        };
        // 24×r32 + new r64 violates (25·64 feature → ~45.6ms > 36ms).
        let crowded = ServerStats {
            running_ranks: vec![32; 24],
            queued_ranks: vec![],
            eligible: true,
            tpot_slo: None,
        };
        let idle = ServerStats {
            running_ranks: vec![],
            queued_ranks: vec![],
            eligible: true,
            tpot_slo: None,
        };
        assert!(sched.calc_cost(&req, &crowded) > 100.0);
        assert!(sched.calc_cost(&req, &idle) < 1.0);
    }

    #[test]
    fn tighter_onboard_slo_triggers_penalty_earlier() {
        let (pre, dec) = models_bgmv();
        let sched = RankAwareScheduler::new(
            pre,
            dec,
            RankAwareConfig {
                slo: 36e-3,
                penalty: 100.0,
                avg_resp_len: 60.0,
            },
        );
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 32,
            prompt_len: 16,
        };
        // A lightly loaded server: within the 36 ms default SLO…
        let mut stats = ServerStats {
            running_ranks: vec![32; 8],
            queued_ranks: vec![],
            eligible: true,
            tpot_slo: None,
        };
        assert!(sched.calc_cost(&req, &stats) < 1.0);
        // …but a resident request carrying a 25 ms SLO flips the penalty.
        stats.tpot_slo = Some(25e-3);
        assert!(sched.calc_cost(&req, &stats) > 100.0);
    }

    #[test]
    fn empty_cluster_returns_none() {
        let (pre, dec) = models_bgmv();
        let mut sched = RankAwareScheduler::new(pre, dec, RankAwareConfig::default());
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 8,
            prompt_len: 16,
        };
        assert_eq!(sched.pick(&req, &[]), None);
    }

    #[test]
    fn prefers_emptier_server_all_else_equal() {
        let (pre, dec) = models_bgmv();
        let mut sched = RankAwareScheduler::new(pre, dec, RankAwareConfig::default());
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 32,
            prompt_len: 16,
        };
        let stats = vec![
            ServerStats {
                running_ranks: vec![32; 10],
                queued_ranks: vec![],
                eligible: true,
                tpot_slo: None,
            },
            ServerStats {
                running_ranks: vec![32; 2],
                queued_ranks: vec![],
                eligible: true,
                tpot_slo: None,
            },
        ];
        assert_eq!(sched.pick(&req, &stats), Some(1));
    }
}
