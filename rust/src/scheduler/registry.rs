//! Global LoRA registry (paper §3): metadata for every adapter in the
//! cluster — rank, base model, weights location — plus which servers
//! currently host it and how much demand each adapter has seen (the
//! popularity counter the [`crate::coordinator`] placement policy and
//! migration engine score by). The paper prototypes this with SQLite;
//! here it is an in-memory store with optional JSON persistence.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::RwLock;

use crate::util::json::{self, Json};

/// Per-event EWMA decay factor for the recency-weighted popularity
/// score: every recorded request advances a global event clock, and an
/// adapter's score is multiplied by `POP_DECAY^age` (age = events since
/// its last update) before the new demand is added. The raw cumulative
/// counter ([`GlobalRegistry::popularity`]) is untouched; the decayed
/// score ([`GlobalRegistry::decayed_popularity`]) is what placement
/// should prefer, because a once-hot adapter that went quiet should
/// lose its device residency claim to currently-hot ones.
const POP_DECAY: f64 = 0.98;

/// Lazy EWMA decay over `age` events, in integer micro-units so the
/// score is exactly representable in JSON and bit-stable across
/// save/load hops.
fn decayed_micro(micro: u64, age: u64) -> u64 {
    if micro == 0 || age == 0 {
        return micro;
    }
    (micro as f64 * POP_DECAY.powi(age.min(i32::MAX as u64) as i32)).round() as u64
}

/// Metadata for one registered adapter.
#[derive(Debug, Clone, PartialEq)]
pub struct AdapterMeta {
    pub id: u64,
    pub rank: usize,
    pub base_model: String,
    /// Path (or URI) of the weights file.
    pub weights_path: String,
}

/// The cluster-wide adapter registry.
#[derive(Default)]
pub struct GlobalRegistry {
    inner: RwLock<Inner>,
}

#[derive(Default)]
struct Inner {
    adapters: BTreeMap<u64, AdapterMeta>,
    /// adapter id → servers hosting it in their local repository. No
    /// entry ever holds an empty set ([`GlobalRegistry::unplace`] prunes).
    placements: BTreeMap<u64, BTreeSet<usize>>,
    /// adapter id → requests observed (routing fronts record each
    /// submission; coordinators may seed historical priors).
    popularity: BTreeMap<u64, u64>,
    /// Global popularity-event clock: total requests ever recorded.
    /// The time base for lazy EWMA decay of `pop_scores`.
    pop_events: u64,
    /// adapter id → (EWMA score in micro-units, event-clock stamp of
    /// its last update). Decay is applied lazily on read/update, so
    /// idle adapters cost nothing until someone looks at them.
    pop_scores: BTreeMap<u64, (u64, u64)>,
}

impl GlobalRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or update) an adapter's metadata.
    pub fn register(&self, meta: AdapterMeta) {
        self.inner.write().unwrap().adapters.insert(meta.id, meta);
    }

    /// Look up an adapter.
    pub fn get(&self, id: u64) -> Option<AdapterMeta> {
        self.inner.read().unwrap().adapters.get(&id).cloned()
    }

    /// Rank of a registered adapter (the scheduler's and the serving
    /// fronts' fast path — avoids cloning the full metadata).
    pub fn rank_of(&self, id: u64) -> Option<usize> {
        self.inner.read().unwrap().adapters.get(&id).map(|m| m.rank)
    }

    /// Record that `server` hosts adapter `id` in its local repository.
    pub fn place(&self, id: u64, server: usize) {
        self.inner
            .write()
            .unwrap()
            .placements
            .entry(id)
            .or_default()
            .insert(server);
    }

    /// Remove a placement. An adapter whose last placement is removed
    /// disappears from the placement table entirely (no empty-set
    /// tombstones accumulate over migration churn).
    pub fn unplace(&self, id: u64, server: usize) {
        let mut inner = self.inner.write().unwrap();
        if let Some(set) = inner.placements.get_mut(&id) {
            set.remove(&server);
            if set.is_empty() {
                inner.placements.remove(&id);
            }
        }
    }

    /// Remove an adapter entirely: metadata, placements, popularity.
    pub fn unregister(&self, id: u64) -> bool {
        let mut inner = self.inner.write().unwrap();
        inner.placements.remove(&id);
        inner.popularity.remove(&id);
        inner.pop_scores.remove(&id);
        inner.adapters.remove(&id).is_some()
    }

    /// Record one observed request against `id` (routing fronts call
    /// this per submission; the coordinator reads it back as demand).
    pub fn record_request(&self, id: u64) {
        self.record_requests(id, 1);
    }

    /// Record `n` observed requests against `id` — bulk form for seeding
    /// a historical demand prior before traffic starts.
    pub fn record_requests(&self, id: u64, n: u64) {
        if n == 0 {
            return;
        }
        let mut inner = self.inner.write().unwrap();
        *inner.popularity.entry(id).or_insert(0) += n;
        inner.pop_events += n;
        let now = inner.pop_events;
        let (micro, last) = inner.pop_scores.get(&id).copied().unwrap_or((0, 0));
        let fresh = decayed_micro(micro, now - last).saturating_add(n.saturating_mul(1_000_000));
        inner.pop_scores.insert(id, (fresh, now));
    }

    /// Requests observed against `id` so far.
    pub fn popularity(&self, id: u64) -> u64 {
        self.inner
            .read()
            .unwrap()
            .popularity
            .get(&id)
            .copied()
            .unwrap_or(0)
    }

    /// `(id, popularity)` for every registered adapter, hottest first
    /// (ties broken by ascending id, so the order is deterministic).
    pub fn popularity_table(&self) -> Vec<(u64, u64)> {
        let inner = self.inner.read().unwrap();
        let mut table: Vec<(u64, u64)> = inner
            .adapters
            .keys()
            .map(|&id| (id, inner.popularity.get(&id).copied().unwrap_or(0)))
            .collect();
        table.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        table
    }

    /// Recency-weighted demand for `id`: the EWMA score decayed by
    /// [`POP_DECAY`] per popularity event since its last request.
    /// Unlike the monotone [`Self::popularity`] counter, this ages out
    /// adapters that have gone quiet — the signal unified-pool-aware
    /// placement should score with.
    pub fn decayed_popularity(&self, id: u64) -> f64 {
        let inner = self.inner.read().unwrap();
        let now = inner.pop_events;
        let (micro, last) = inner.pop_scores.get(&id).copied().unwrap_or((0, 0));
        decayed_micro(micro, now - last) as f64 / 1e6
    }

    /// `(id, decayed score)` for every registered adapter, hottest
    /// first (ties by ascending id — deterministic like
    /// [`Self::popularity_table`], but recency-weighted).
    pub fn decayed_table(&self) -> Vec<(u64, f64)> {
        let inner = self.inner.read().unwrap();
        let now = inner.pop_events;
        let mut table: Vec<(u64, f64)> = inner
            .adapters
            .keys()
            .map(|&id| {
                let (micro, last) = inner.pop_scores.get(&id).copied().unwrap_or((0, 0));
                (id, decayed_micro(micro, now - last) as f64 / 1e6)
            })
            .collect();
        table.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        table
    }

    /// Number of adapters with at least one recorded placement.
    pub fn placed_len(&self) -> usize {
        self.inner.read().unwrap().placements.len()
    }

    /// Servers hosting adapter `id`.
    pub fn servers_for(&self, id: u64) -> Vec<usize> {
        self.inner
            .read()
            .unwrap()
            .placements
            .get(&id)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    /// All registered adapter ids (sorted — `BTreeMap` order), e.g. for
    /// building an [`crate::scheduler::AdapterSet`].
    pub fn ids(&self) -> Vec<u64> {
        self.inner.read().unwrap().adapters.keys().copied().collect()
    }

    /// Number of registered adapters.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().adapters.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialize the registry to JSON.
    pub fn to_json(&self) -> Json {
        let inner = self.inner.read().unwrap();
        let adapters: Vec<Json> = inner
            .adapters
            .values()
            .map(|m| {
                let pop = inner.popularity.get(&m.id).copied().unwrap_or(0);
                let (micro, last) = inner.pop_scores.get(&m.id).copied().unwrap_or((0, 0));
                json::obj(vec![
                    ("id", json::num(m.id as f64)),
                    ("rank", json::num(m.rank as f64)),
                    ("base_model", json::s(&m.base_model)),
                    ("weights_path", json::s(&m.weights_path)),
                    ("popularity", json::num(pop as f64)),
                    ("pop_score_micro", json::num(micro as f64)),
                    ("pop_last_event", json::num(last as f64)),
                    (
                        "servers",
                        Json::Arr(
                            inner
                                .placements
                                .get(&m.id)
                                .map(|s| {
                                    s.iter().map(|&x| json::num(x as f64)).collect()
                                })
                                .unwrap_or_default(),
                        ),
                    ),
                ])
            })
            .collect();
        json::obj(vec![
            ("adapters", Json::Arr(adapters)),
            ("pop_events", json::num(inner.pop_events as f64)),
        ])
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    /// Load from a JSON file produced by [`Self::save`].
    pub fn load(path: &Path) -> anyhow::Result<GlobalRegistry> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let reg = GlobalRegistry::new();
        let mut scores: Vec<(u64, u64, u64)> = Vec::new();
        for item in j.req("adapters").map_err(|e| anyhow::anyhow!("{e}"))?.as_arr().unwrap_or(&[]) {
            let id = item
                .get("id")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("bad id"))? as u64;
            let rank = item
                .get("rank")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("bad rank"))?;
            let base_model = item
                .get("base_model")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string();
            let weights_path = item
                .get("weights_path")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string();
            reg.register(AdapterMeta {
                id,
                rank,
                base_model,
                weights_path,
            });
            if let Some(servers) = item.get("servers").and_then(Json::as_arr) {
                for s in servers {
                    if let Some(sv) = s.as_usize() {
                        reg.place(id, sv);
                    }
                }
            }
            // Popularity is optional (older files predate the counter).
            // Replaying it through `record_requests` doubles as the
            // legacy backfill for the EWMA score; files carrying the
            // explicit score fields overwrite that replay below.
            if let Some(pop) = item.get("popularity").and_then(Json::as_f64) {
                if pop > 0.0 {
                    reg.record_requests(id, pop as u64);
                }
            }
            if let (Some(micro), Some(last)) = (
                item.get("pop_score_micro").and_then(Json::as_f64),
                item.get("pop_last_event").and_then(Json::as_f64),
            ) {
                scores.push((id, micro as u64, last as u64));
            }
        }
        // New-format files persist the decayed scores exactly; restore
        // them verbatim so save → load → save is byte-stable and decay
        // resumes from the saved event clock, not a replayed one.
        if let Some(events) = j.get("pop_events").and_then(Json::as_f64) {
            let mut inner = reg.inner.write().unwrap();
            inner.pop_events = events as u64;
            for (id, micro, last) in scores {
                inner.pop_scores.insert(id, (micro, last));
            }
        }
        Ok(reg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(id: u64, rank: usize) -> AdapterMeta {
        AdapterMeta {
            id,
            rank,
            base_model: "llama2-7b".into(),
            weights_path: format!("/adapters/{id}.npz"),
        }
    }

    #[test]
    fn register_and_lookup() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.register(meta(2, 8));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.ids(), vec![1, 2]);
        assert_eq!(reg.get(1).unwrap().rank, 64);
        assert_eq!(reg.rank_of(1), Some(64));
        assert!(reg.get(99).is_none());
        assert!(reg.rank_of(99).is_none());
    }

    #[test]
    fn placements_tracked() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.place(1, 0);
        reg.place(1, 3);
        reg.place(1, 3); // idempotent
        assert_eq!(reg.servers_for(1), vec![0, 3]);
        reg.unplace(1, 0);
        assert_eq!(reg.servers_for(1), vec![3]);
        assert!(reg.servers_for(42).is_empty());
    }

    #[test]
    fn unplace_prunes_empty_entries() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.register(meta(2, 8));
        reg.place(1, 0);
        reg.place(2, 1);
        assert_eq!(reg.placed_len(), 2);
        reg.unplace(1, 0);
        // The emptied entry is gone, not an empty-set tombstone.
        assert_eq!(reg.placed_len(), 1);
        assert!(reg.servers_for(1).is_empty());
        // Unplacing a never-placed or already-empty id is a no-op.
        reg.unplace(1, 5);
        reg.unplace(99, 0);
        assert_eq!(reg.placed_len(), 1);
    }

    #[test]
    fn popularity_accumulates_and_orders() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.register(meta(2, 8));
        reg.register(meta(3, 16));
        assert_eq!(reg.popularity(1), 0);
        reg.record_request(2);
        reg.record_request(2);
        reg.record_requests(3, 5);
        assert_eq!(reg.popularity(2), 2);
        assert_eq!(reg.popularity(3), 5);
        // Hottest first, ties (zero-demand adapters) by ascending id.
        assert_eq!(reg.popularity_table(), vec![(3, 5), (2, 2), (1, 0)]);
    }

    #[test]
    fn decayed_popularity_ages_out_stale_demand() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.register(meta(2, 8));
        reg.register(meta(3, 16));
        // Adapter 1 was hot early; 80 events of unrelated traffic pass;
        // adapter 2 gets modest but *recent* demand.
        reg.record_requests(1, 10);
        reg.record_requests(3, 80);
        reg.record_requests(2, 8);
        // The raw counter still ranks 1 over 2 (10 > 8)…
        assert_eq!(reg.popularity_table(), vec![(3, 80), (1, 10), (2, 8)]);
        // …but the decayed score has aged 1 out: 10·0.98^88 ≈ 1.7 < 8.
        assert!(reg.decayed_popularity(1) < reg.decayed_popularity(2));
        assert!(reg.decayed_popularity(1) < 10.0);
        let order: Vec<u64> = reg.decayed_table().iter().map(|&(id, _)| id).collect();
        assert_eq!(order, vec![3, 2, 1]);
    }

    #[test]
    fn save_load_roundtrip_preserves_decayed_scores() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.register(meta(2, 8));
        reg.record_requests(1, 10);
        reg.record_requests(2, 40);
        let dir = std::env::temp_dir().join("caraserve-registry-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("registry_decay.json");
        reg.save(&path).unwrap();
        let back = GlobalRegistry::load(&path).unwrap();
        // Raw counters and decayed scores both survive persistence
        // exactly (scores live in integer micro-units for this).
        assert_eq!(back.popularity(1), 10);
        assert_eq!(back.popularity(2), 40);
        assert_eq!(back.decayed_popularity(1), reg.decayed_popularity(1));
        assert_eq!(back.decayed_popularity(2), reg.decayed_popularity(2));
        assert_eq!(back.decayed_table(), reg.decayed_table());
        // A second hop is byte-stable.
        let path2 = dir.join("registry_decay2.json");
        back.save(&path2).unwrap();
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            std::fs::read_to_string(&path2).unwrap()
        );
        // Decay resumes from the restored event clock: identical new
        // demand leaves both registries in identical states.
        reg.record_request(2);
        back.record_request(2);
        assert_eq!(back.decayed_popularity(1), reg.decayed_popularity(1));
        assert_eq!(back.decayed_popularity(2), reg.decayed_popularity(2));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn unregister_drops_all_state() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.place(1, 0);
        reg.record_request(1);
        assert!(reg.unregister(1));
        assert!(!reg.unregister(1));
        assert!(reg.get(1).is_none());
        assert!(reg.servers_for(1).is_empty());
        assert_eq!(reg.popularity(1), 0);
        assert_eq!(reg.placed_len(), 0);
    }

    #[test]
    fn save_load_roundtrip() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.register(meta(7, 16));
        reg.place(7, 2);
        let dir = std::env::temp_dir().join("caraserve-registry-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("registry.json");
        reg.save(&path).unwrap();
        let back = GlobalRegistry::load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.get(7).unwrap().rank, 16);
        assert_eq!(back.servers_for(7), vec![2]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_load_roundtrip_covers_placements_and_popularity() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.register(meta(2, 8));
        reg.register(meta(3, 32));
        reg.place(1, 0);
        reg.place(1, 4);
        reg.place(2, 1);
        reg.place(3, 2);
        reg.unplace(3, 2); // pruned: must not resurrect on load
        reg.record_requests(1, 12);
        reg.record_request(2);
        let dir = std::env::temp_dir().join("caraserve-registry-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("registry_placements.json");
        reg.save(&path).unwrap();
        let back = GlobalRegistry::load(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.servers_for(1), vec![0, 4]);
        assert_eq!(back.servers_for(2), vec![1]);
        assert!(back.servers_for(3).is_empty());
        assert_eq!(back.placed_len(), 2);
        assert_eq!(back.popularity(1), 12);
        assert_eq!(back.popularity(2), 1);
        assert_eq!(back.popularity(3), 0);
        assert_eq!(back.popularity_table(), reg.popularity_table());
        // A second hop is byte-stable (BTreeMap ordering everywhere).
        let path2 = dir.join("registry_placements2.json");
        back.save(&path2).unwrap();
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            std::fs::read_to_string(&path2).unwrap()
        );
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn update_overwrites() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 8));
        reg.register(meta(1, 32));
        assert_eq!(reg.get(1).unwrap().rank, 32);
        assert_eq!(reg.len(), 1);
    }
}
