//! Global LoRA registry (paper §3): metadata for every adapter in the
//! cluster — rank, base model, weights location — plus which servers
//! currently host it. The paper prototypes this with SQLite; here it is
//! an in-memory store with optional JSON persistence.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::RwLock;

use crate::util::json::{self, Json};

/// Metadata for one registered adapter.
#[derive(Debug, Clone, PartialEq)]
pub struct AdapterMeta {
    pub id: u64,
    pub rank: usize,
    pub base_model: String,
    /// Path (or URI) of the weights file.
    pub weights_path: String,
}

/// The cluster-wide adapter registry.
#[derive(Default)]
pub struct GlobalRegistry {
    inner: RwLock<Inner>,
}

#[derive(Default)]
struct Inner {
    adapters: BTreeMap<u64, AdapterMeta>,
    /// adapter id → servers hosting it in their local repository.
    placements: BTreeMap<u64, BTreeSet<usize>>,
}

impl GlobalRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or update) an adapter's metadata.
    pub fn register(&self, meta: AdapterMeta) {
        self.inner.write().unwrap().adapters.insert(meta.id, meta);
    }

    /// Look up an adapter.
    pub fn get(&self, id: u64) -> Option<AdapterMeta> {
        self.inner.read().unwrap().adapters.get(&id).cloned()
    }

    /// Rank of a registered adapter (the scheduler's and the serving
    /// fronts' fast path — avoids cloning the full metadata).
    pub fn rank_of(&self, id: u64) -> Option<usize> {
        self.inner.read().unwrap().adapters.get(&id).map(|m| m.rank)
    }

    /// Record that `server` hosts adapter `id` in its local repository.
    pub fn place(&self, id: u64, server: usize) {
        self.inner
            .write()
            .unwrap()
            .placements
            .entry(id)
            .or_default()
            .insert(server);
    }

    /// Remove a placement.
    pub fn unplace(&self, id: u64, server: usize) {
        if let Some(set) = self.inner.write().unwrap().placements.get_mut(&id) {
            set.remove(&server);
        }
    }

    /// Servers hosting adapter `id`.
    pub fn servers_for(&self, id: u64) -> Vec<usize> {
        self.inner
            .read()
            .unwrap()
            .placements
            .get(&id)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    /// All registered adapter ids (sorted — `BTreeMap` order), e.g. for
    /// building an [`crate::scheduler::AdapterSet`].
    pub fn ids(&self) -> Vec<u64> {
        self.inner.read().unwrap().adapters.keys().copied().collect()
    }

    /// Number of registered adapters.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().adapters.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialize the registry to JSON.
    pub fn to_json(&self) -> Json {
        let inner = self.inner.read().unwrap();
        let adapters: Vec<Json> = inner
            .adapters
            .values()
            .map(|m| {
                json::obj(vec![
                    ("id", json::num(m.id as f64)),
                    ("rank", json::num(m.rank as f64)),
                    ("base_model", json::s(&m.base_model)),
                    ("weights_path", json::s(&m.weights_path)),
                    (
                        "servers",
                        Json::Arr(
                            inner
                                .placements
                                .get(&m.id)
                                .map(|s| {
                                    s.iter().map(|&x| json::num(x as f64)).collect()
                                })
                                .unwrap_or_default(),
                        ),
                    ),
                ])
            })
            .collect();
        json::obj(vec![("adapters", Json::Arr(adapters))])
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    /// Load from a JSON file produced by [`Self::save`].
    pub fn load(path: &Path) -> anyhow::Result<GlobalRegistry> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let reg = GlobalRegistry::new();
        for item in j.req("adapters").map_err(|e| anyhow::anyhow!("{e}"))?.as_arr().unwrap_or(&[]) {
            let id = item
                .get("id")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("bad id"))? as u64;
            let rank = item
                .get("rank")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("bad rank"))?;
            let base_model = item
                .get("base_model")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string();
            let weights_path = item
                .get("weights_path")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string();
            reg.register(AdapterMeta {
                id,
                rank,
                base_model,
                weights_path,
            });
            if let Some(servers) = item.get("servers").and_then(Json::as_arr) {
                for s in servers {
                    if let Some(sv) = s.as_usize() {
                        reg.place(id, sv);
                    }
                }
            }
        }
        Ok(reg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(id: u64, rank: usize) -> AdapterMeta {
        AdapterMeta {
            id,
            rank,
            base_model: "llama2-7b".into(),
            weights_path: format!("/adapters/{id}.npz"),
        }
    }

    #[test]
    fn register_and_lookup() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.register(meta(2, 8));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.ids(), vec![1, 2]);
        assert_eq!(reg.get(1).unwrap().rank, 64);
        assert_eq!(reg.rank_of(1), Some(64));
        assert!(reg.get(99).is_none());
        assert!(reg.rank_of(99).is_none());
    }

    #[test]
    fn placements_tracked() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.place(1, 0);
        reg.place(1, 3);
        reg.place(1, 3); // idempotent
        assert_eq!(reg.servers_for(1), vec![0, 3]);
        reg.unplace(1, 0);
        assert_eq!(reg.servers_for(1), vec![3]);
        assert!(reg.servers_for(42).is_empty());
    }

    #[test]
    fn save_load_roundtrip() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 64));
        reg.register(meta(7, 16));
        reg.place(7, 2);
        let dir = std::env::temp_dir().join("caraserve-registry-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("registry.json");
        reg.save(&path).unwrap();
        let back = GlobalRegistry::load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.get(7).unwrap().rank, 16);
        assert_eq!(back.servers_for(7), vec![2]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn update_overwrites() {
        let reg = GlobalRegistry::new();
        reg.register(meta(1, 8));
        reg.register(meta(1, 32));
        assert_eq!(reg.get(1).unwrap().rank, 32);
        assert_eq!(reg.len(), 1);
    }
}
