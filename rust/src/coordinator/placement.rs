//! Registry-driven adapter placement (paper §3): decide which servers
//! host which adapters *before* traffic arrives, from the metadata the
//! [`crate::scheduler::registry::GlobalRegistry`] already tracks.
//!
//! The policy is a deterministic greedy pack over a demand-weighted
//! score. Each adapter carries a weight
//!
//! ```text
//! weight = (popularity + 1) × rank
//! ```
//!
//! — popularity because a hot adapter's host absorbs its traffic, rank
//! because a high-rank adapter inflates every batch it decodes in (the
//! BGMV cost the §5 performance models fit) *and* costs more slot
//! memory. Adapters are placed hottest-first; each replica goes to the
//! server minimizing
//!
//! ```text
//! score(s) = load(s) + weight × count(s) / slots_per_server
//! ```
//!
//! where `load(s)` is the demand weight already packed onto `s` and the
//! second term is the **slot pressure** penalty: once a server's
//! adapter count approaches its device-slot capacity, further adapters
//! there cold-start (slot eviction churn), so the policy pays
//! proportionally more to co-locate. Ties break on the lower server
//! index, so placements are reproducible run to run.
//!
//! The output is a per-server adapter list; the
//! [`crate::coordinator::Coordinator`] installs it through
//! [`crate::server::ClusterFront::install_on`] and pre-warms the
//! hottest adapters ([`top_hot`]) so first requests admit warm.

/// One adapter as the placement policy sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlacementInput {
    /// Adapter id.
    pub id: u64,
    /// LoRA rank (slot memory + batch-cost proxy).
    pub rank: usize,
    /// Observed or seeded demand (requests).
    pub popularity: u64,
}

/// Knobs for one placement computation.
#[derive(Debug, Clone, Copy)]
pub struct PlacementConfig {
    /// Number of servers to place onto.
    pub servers: usize,
    /// Replicas per adapter (clamped to the server count).
    pub replicas: usize,
    /// Device LoRA slots per server — the denominator of the
    /// slot-pressure penalty.
    pub slots_per_server: usize,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        PlacementConfig {
            servers: 1,
            replicas: 1,
            slots_per_server: 8,
        }
    }
}

/// Demand weight of one adapter: `(popularity + 1) × rank`. The `+ 1`
/// keeps zero-demand adapters orderable by rank instead of collapsing
/// to a single zero bucket.
pub fn weight(a: &PlacementInput) -> f64 {
    (a.popularity as f64 + 1.0) * a.rank.max(1) as f64
}

/// Compute placements: `out[s]` lists the adapter ids server `s` hosts.
/// Every adapter lands on exactly `min(replicas, servers)` distinct
/// servers; the assignment greedily balances demand weight under the
/// slot-pressure penalty (see module docs). Deterministic.
pub fn compute(adapters: &[PlacementInput], cfg: &PlacementConfig) -> Vec<Vec<u64>> {
    assert!(cfg.servers > 0, "placement over zero servers");
    let replicas = cfg.replicas.clamp(1, cfg.servers);
    let slots = cfg.slots_per_server.max(1) as f64;

    // Hottest (heaviest) first, ties by ascending id for determinism.
    let mut order: Vec<&PlacementInput> = adapters.iter().collect();
    order.sort_by(|a, b| {
        weight(b)
            .partial_cmp(&weight(a))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });

    let mut out: Vec<Vec<u64>> = vec![Vec::new(); cfg.servers];
    let mut load = vec![0.0f64; cfg.servers];
    for a in order {
        let w = weight(a);
        let mut chosen: Vec<usize> = Vec::with_capacity(replicas);
        for _ in 0..replicas {
            let s = (0..cfg.servers)
                .filter(|s| !chosen.contains(s))
                .min_by(|&x, &y| {
                    let sx = load[x] + w * out[x].len() as f64 / slots;
                    let sy = load[y] + w * out[y].len() as f64 / slots;
                    sx.partial_cmp(&sy).unwrap_or(std::cmp::Ordering::Equal)
                })
                .expect("replicas clamped to server count");
            chosen.push(s);
            load[s] += w;
            out[s].push(a.id);
        }
    }
    out
}

/// One adapter as the **unified-pool-aware** placement policy sees it:
/// demand is the recency-weighted score from
/// [`crate::scheduler::registry::GlobalRegistry::decayed_popularity`]
/// (EWMA-decayed, so once-hot-now-quiet adapters lose their claim)
/// rather than the monotone counter, and the adapter carries its
/// device-memory footprint in unified-pool pages.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PagedPlacementInput {
    /// Adapter id.
    pub id: u64,
    /// LoRA rank (batch-cost proxy).
    pub rank: usize,
    /// Recency-weighted demand (decayed popularity score).
    pub demand: f64,
    /// Unified-pool pages the adapter's weights hold while resident.
    /// Exact counts are runtime-dependent (hidden size, page geometry);
    /// only the *relative* footprint steers the score, and that is
    /// rank-proportional.
    pub pages: usize,
}

/// Demand weight of one paged adapter: `(demand + 1) × rank` — the
/// decayed analogue of [`weight`].
pub fn paged_weight(a: &PagedPlacementInput) -> f64 {
    (a.demand + 1.0) * a.rank.max(1) as f64
}

/// Unified-pool-aware placement: the same greedy pack as [`compute`],
/// but the pressure penalty charges **memory**, not just slots —
///
/// ```text
/// score(s) = load(s) + weight × (count(s)/slots + pages(s)/pool_pages)
/// ```
///
/// A server whose resident adapters already hold a large share of its
/// unified pool (pages that would otherwise back KV blocks) pays
/// proportionally more for further co-location, so fat-footprint
/// adapters spread instead of starving one server's KV headroom.
/// Deterministic; ties break on the lower server index.
pub fn compute_paged(
    adapters: &[PagedPlacementInput],
    cfg: &PlacementConfig,
    pool_pages: usize,
) -> Vec<Vec<u64>> {
    assert!(cfg.servers > 0, "placement over zero servers");
    let replicas = cfg.replicas.clamp(1, cfg.servers);
    let slots = cfg.slots_per_server.max(1) as f64;
    let pool = pool_pages.max(1) as f64;

    // Hottest (heaviest) first, ties by ascending id for determinism.
    let mut order: Vec<&PagedPlacementInput> = adapters.iter().collect();
    order.sort_by(|a, b| {
        paged_weight(b)
            .total_cmp(&paged_weight(a))
            .then(a.id.cmp(&b.id))
    });

    let mut out: Vec<Vec<u64>> = vec![Vec::new(); cfg.servers];
    let mut load = vec![0.0f64; cfg.servers];
    let mut pages = vec![0usize; cfg.servers];
    for a in order {
        let w = paged_weight(a);
        let mut chosen: Vec<usize> = Vec::with_capacity(replicas);
        for _ in 0..replicas {
            let s = (0..cfg.servers)
                .filter(|s| !chosen.contains(s))
                .min_by(|&x, &y| {
                    let px =
                        load[x] + w * (out[x].len() as f64 / slots + pages[x] as f64 / pool);
                    let py =
                        load[y] + w * (out[y].len() as f64 / slots + pages[y] as f64 / pool);
                    px.total_cmp(&py)
                })
                .expect("replicas clamped to server count");
            chosen.push(s);
            load[s] += w;
            pages[s] += a.pages;
            out[s].push(a.id);
        }
    }
    out
}

/// The `k` hottest paged adapters by [`paged_weight`] — the pre-paging
/// set under the unified pool.
pub fn top_hot_paged(adapters: &[PagedPlacementInput], k: usize) -> Vec<u64> {
    let mut order: Vec<&PagedPlacementInput> = adapters.iter().collect();
    order.sort_by(|a, b| {
        paged_weight(b)
            .total_cmp(&paged_weight(a))
            .then(a.id.cmp(&b.id))
    });
    order.into_iter().take(k).map(|a| a.id).collect()
}

/// The `k` hottest adapters (strictly by descending weight, ties by
/// ascending id) — the pre-warm set.
pub fn top_hot(adapters: &[PlacementInput], k: usize) -> Vec<u64> {
    let mut order: Vec<&PlacementInput> = adapters.iter().collect();
    order.sort_by(|a, b| {
        weight(b)
            .partial_cmp(&weight(a))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    order.into_iter().take(k).map(|a| a.id).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(id: u64, rank: usize, popularity: u64) -> PlacementInput {
        PlacementInput {
            id,
            rank,
            popularity,
        }
    }

    #[test]
    fn every_adapter_placed_with_distinct_replicas() {
        let adapters: Vec<PlacementInput> =
            (0..10).map(|id| input(id, 8 << (id % 4), id)).collect();
        let cfg = PlacementConfig {
            servers: 3,
            replicas: 2,
            slots_per_server: 8,
        };
        let placements = compute(&adapters, &cfg);
        assert_eq!(placements.len(), 3);
        for a in &adapters {
            let hosts: Vec<usize> = (0..3)
                .filter(|&s| placements[s].contains(&a.id))
                .collect();
            assert_eq!(hosts.len(), 2, "adapter {} on {hosts:?}", a.id);
        }
    }

    #[test]
    fn replicas_clamped_to_server_count() {
        let adapters = vec![input(0, 8, 5)];
        let cfg = PlacementConfig {
            servers: 2,
            replicas: 9,
            slots_per_server: 8,
        };
        let placements = compute(&adapters, &cfg);
        assert!(placements[0].contains(&0) && placements[1].contains(&0));
    }

    #[test]
    fn hot_adapters_spread_across_servers() {
        // Two very hot adapters must not share a server while cold ones
        // pack wherever: the demand load dominates the score.
        let mut adapters = vec![input(0, 64, 1000), input(1, 64, 900)];
        adapters.extend((2..8).map(|id| input(id, 8, 1)));
        let cfg = PlacementConfig {
            servers: 2,
            replicas: 1,
            slots_per_server: 8,
        };
        let placements = compute(&adapters, &cfg);
        let host_of = |id: u64| (0..2).find(|&s| placements[s].contains(&id)).unwrap();
        assert_ne!(host_of(0), host_of(1), "{placements:?}");
    }

    #[test]
    fn slot_pressure_spills_before_overpacking() {
        // Nine equal-demand adapters over three servers with three slots
        // each: the pressure penalty forces a 3/3/3 split rather than
        // piling onto one server.
        let adapters: Vec<PlacementInput> = (0..9).map(|id| input(id, 8, 10)).collect();
        let cfg = PlacementConfig {
            servers: 3,
            replicas: 1,
            slots_per_server: 3,
        };
        let placements = compute(&adapters, &cfg);
        for s in 0..3 {
            assert_eq!(placements[s].len(), 3, "{placements:?}");
        }
    }

    #[test]
    fn deterministic_given_same_inputs() {
        let adapters: Vec<PlacementInput> =
            (0..12).map(|id| input(id, 8 << (id % 4), 12 - id)).collect();
        let cfg = PlacementConfig {
            servers: 4,
            replicas: 2,
            slots_per_server: 8,
        };
        assert_eq!(compute(&adapters, &cfg), compute(&adapters, &cfg));
    }

    fn paged(id: u64, rank: usize, demand: f64, pages: usize) -> PagedPlacementInput {
        PagedPlacementInput {
            id,
            rank,
            demand,
            pages,
        }
    }

    #[test]
    fn paged_pressure_spreads_fat_footprints() {
        // Three zero-demand adapters, equal rank: one holds 6 of the 8
        // pool pages, two hold 1 each. The slot-only policy would pack
        // the third adapter back onto server 0 (counts tie); the paged
        // score sees server 0's pool nearly full and spills to 1.
        let adapters = vec![paged(0, 8, 0.0, 6), paged(1, 8, 0.0, 1), paged(2, 8, 0.0, 1)];
        let cfg = PlacementConfig {
            servers: 2,
            replicas: 1,
            slots_per_server: 8,
        };
        let placements = compute_paged(&adapters, &cfg, 8);
        assert_eq!(placements, vec![vec![0], vec![1, 2]]);
        // The slot-only policy on the same shape co-locates 0 and 2.
        let legacy: Vec<PlacementInput> = adapters
            .iter()
            .map(|a| input(a.id, a.rank, a.demand as u64))
            .collect();
        assert_eq!(compute(&legacy, &cfg), vec![vec![0, 2], vec![1]]);
    }

    #[test]
    fn paged_compute_deterministic_and_complete() {
        let adapters: Vec<PagedPlacementInput> = (0..12)
            .map(|id| paged(id, 8 << (id % 4), (12 - id) as f64, 1 + (id % 4) as usize))
            .collect();
        let cfg = PlacementConfig {
            servers: 3,
            replicas: 2,
            slots_per_server: 8,
        };
        let placements = compute_paged(&adapters, &cfg, 64);
        assert_eq!(placements, compute_paged(&adapters, &cfg, 64));
        for a in &adapters {
            let hosts = (0..3).filter(|&s| placements[s].contains(&a.id)).count();
            assert_eq!(hosts, 2, "adapter {}", a.id);
        }
    }

    #[test]
    fn top_hot_paged_orders_by_decayed_weight() {
        let adapters = vec![
            paged(3, 8, 100.0, 1),  // weight 808
            paged(1, 64, 10.0, 4),  // weight 704
            paged(2, 64, 10.0, 4),  // weight 704 (tie → id order)
            paged(0, 8, 0.0, 1),    // weight 8
        ];
        assert_eq!(top_hot_paged(&adapters, 3), vec![3, 1, 2]);
        assert_eq!(top_hot_paged(&adapters, 0), Vec::<u64>::new());
    }

    #[test]
    fn top_hot_orders_by_weight_then_id() {
        let adapters = vec![
            input(3, 8, 100),  // weight 808
            input(1, 64, 10),  // weight 704
            input(2, 64, 10),  // weight 704 (tie with 1 → id order)
            input(0, 8, 0),    // weight 8
        ];
        assert_eq!(top_hot(&adapters, 3), vec![3, 1, 2]);
        assert_eq!(top_hot(&adapters, 0), Vec::<u64>::new());
        assert_eq!(top_hot(&adapters, 99).len(), 4);
    }
}
