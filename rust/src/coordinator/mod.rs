//! The global coordinator (paper §3): the control-plane tier above the
//! per-server engines.
//!
//! CaraServe's architecture splits serving into per-server data planes
//! (the [`crate::server::InferenceServer`]s behind a
//! [`crate::server::ClusterFront`]) and one cluster-wide control plane
//! that owns the adapter registry, decides which servers host which
//! adapters, and pre-warms the hot ones. This module reproduces that
//! role on top of the routed cluster:
//!
//! - **Registry-driven placement** ([`placement`]): instead of a static
//!   id-hash assignment, initial placements are computed from the
//!   [`GlobalRegistry`]'s metadata — demand (popularity counter) ×
//!   rank × per-server slot pressure — and installed through
//!   [`ClusterFront::install_on`], which updates backend and registry
//!   together. The top-K hot adapters are **pre-warmed** into their
//!   device slots before the first request, so the skewed head admits
//!   warm.
//! - **Live migration**: every `migrate_interval` polls the coordinator
//!   inspects the per-server [`ServerStats`] (queue depth, running
//!   batch, KV headroom, decode-growth preemptions) and, when one
//!   server runs hot while another idles, **replicates the most popular
//!   adapter** unique to the saturated server onto the idle one — then
//!   (in `Move` mode) retires the source copy once its in-flight
//!   requests drain. Uninstall refuses while requests on the adapter
//!   are live, so a migrated adapter's token streams are bitwise
//!   unaffected; refusals are retried on later ticks and counted in
//!   [`CoordinatorStats::deferred_retirements`].
//!
//! The [`Coordinator`] itself implements [`ServingFront`], so any
//! driver written for one engine (or a bare cluster) runs unchanged
//! with the control plane active; `caraserve coordinator` drives it
//! against live native engines and `benches/placement.rs` measures
//! static vs coordinated placement on a skewed workload.

pub mod placement;

use std::path::Path;

use anyhow::Result;

use crate::model::LoraSpec;
use crate::scheduler::registry::GlobalRegistry;
use crate::scheduler::ServerStats;
use crate::server::api::{InstallSourceStats, RequestHandle, ServeRequest, ServingFront};
use crate::server::metrics::ColdStartStats;
use crate::server::ClusterFront;
use self::placement::{PagedPlacementInput, PlacementConfig, PlacementInput};

/// What to do with the source copy after a migration replicates an
/// adapter onto a relief server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationMode {
    /// Keep both copies (pure replication — more capacity for the hot
    /// adapter, more slot pressure on the source).
    Replicate,
    /// Retire the source copy once its in-flight requests drain (a true
    /// move; the default).
    Move,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Cluster polls between rebalance ticks (0 disables migration).
    pub migrate_interval: usize,
    /// Pre-warm the K hottest adapters at placement time.
    pub prewarm: usize,
    /// Initial replicas per adapter (clamped to the server count).
    pub replicas: usize,
    /// Device LoRA slots per server (the slot-pressure denominator).
    pub slots_per_server: usize,
    /// Minimum load gap (see [`Coordinator::load_of`]) between the
    /// busiest and idlest server before a migration fires.
    pub min_imbalance: usize,
    /// Replicate or move (see [`MigrationMode`]).
    pub mode: MigrationMode,
    /// Per-server unified-pool size, in pages. `Some(p)` switches
    /// initial placement to the memory-aware policy
    /// ([`placement::compute_paged`]): demand comes from the registry's
    /// EWMA-decayed popularity and the pressure penalty charges
    /// rank-proportional page footprints against `p`. `None` (the
    /// default) keeps the legacy slot-pressure-only policy.
    pub pool_pages: Option<usize>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            migrate_interval: 8,
            prewarm: 4,
            replicas: 1,
            slots_per_server: 8,
            min_imbalance: 2,
            mode: MigrationMode::Move,
            pool_pages: None,
        }
    }
}

/// One recorded migration decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationEvent {
    /// The migrated adapter.
    pub adapter: u64,
    /// Saturated source server.
    pub from: usize,
    /// Relief target server.
    pub to: usize,
}

/// Control-plane counters — the coordinator-side analogue of
/// [`ColdStartStats`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CoordinatorStats {
    /// Adapter→server installs performed at initial placement.
    pub initial_placements: usize,
    /// Adapters made device-resident ahead of traffic.
    pub prewarmed: usize,
    /// Rebalance inspections run.
    pub rebalance_ticks: usize,
    /// Runtime migrations: hot-adapter installs onto relief servers.
    pub migrations: usize,
    /// Source copies retired after a `Move` migration.
    pub retirements: usize,
    /// Retire attempts refused because requests were still in flight on
    /// the source (each refusal counts; the retire retries next tick).
    pub deferred_retirements: usize,
}

/// The global coordinator: a [`ClusterFront`] plus the §3 control
/// plane. See the module docs.
pub struct Coordinator {
    cluster: ClusterFront,
    cfg: CoordinatorConfig,
    stats: CoordinatorStats,
    /// Poll counter driving the rebalance cadence.
    polls: usize,
    /// Per-server preemption counts at the previous rebalance tick —
    /// `ServerStats::preemptions` is a lifetime counter, so the load
    /// score uses the delta since last tick, not the monotone total
    /// (one historical preemption must not bias migration forever).
    last_preemptions: Vec<usize>,
    /// `Move`-mode source copies awaiting a drain (adapter, server).
    pending_retire: Vec<(u64, usize)>,
    /// Migration decisions, oldest first.
    log: Vec<MigrationEvent>,
}

impl Coordinator {
    /// Put the control plane in front of a routed cluster. Call
    /// [`Coordinator::place_and_prewarm`] before traffic when the
    /// cluster was built without static placements.
    pub fn new(cluster: ClusterFront, cfg: CoordinatorConfig) -> Coordinator {
        Coordinator {
            cluster,
            cfg,
            stats: CoordinatorStats::default(),
            polls: 0,
            last_preemptions: Vec::new(),
            pending_retire: Vec::new(),
            log: Vec::new(),
        }
    }

    /// The routed cluster behind the control plane.
    pub fn cluster(&self) -> &ClusterFront {
        &self.cluster
    }

    /// Mutable access to the routed cluster (tests, targeted ops).
    pub fn cluster_mut(&mut self) -> &mut ClusterFront {
        &mut self.cluster
    }

    /// Control-plane counters.
    pub fn coordinator_stats(&self) -> &CoordinatorStats {
        &self.stats
    }

    /// Migration decisions so far, oldest first.
    pub fn migration_log(&self) -> &[MigrationEvent] {
        &self.log
    }

    /// Persist the control-plane state to `path`. The
    /// [`GlobalRegistry`] snapshot (metadata, placements, demand
    /// counters, decayed scores) is the coordinator's full durable
    /// state: everything else — health, routing counters, the rebalance
    /// clock — is soft state a restarted coordinator rebuilds from
    /// traffic. Call on a cadence (or before shutdown) so a
    /// crash-restart resumes from the last snapshot.
    pub fn save_state(&self, path: &Path) -> std::io::Result<()> {
        self.cluster.registry().save(path)
    }

    /// Rebuild a coordinator after a crash-restart: load the registry
    /// snapshot from `path`, put the control plane over `backends`
    /// (fresh, empty engines), and re-install every recorded placement
    /// so the restarted cluster serves exactly the adapters — on
    /// exactly the servers — the dead coordinator had placed.
    pub fn load_state(
        path: &Path,
        backends: Vec<Box<dyn ServingFront>>,
        policy: Box<dyn crate::scheduler::Policy>,
        cfg: CoordinatorConfig,
    ) -> Result<Coordinator> {
        let registry = std::sync::Arc::new(GlobalRegistry::load(path)?);
        let cluster = ClusterFront::new(backends, policy, registry);
        let mut coord = Coordinator::new(cluster, cfg);
        coord.restore_placements()?;
        Ok(coord)
    }

    /// Re-install every placement recorded in the registry onto the
    /// current backends. Used after a crash-restart, when the registry
    /// remembers the placements but the (restarted) backends came up
    /// empty. Idempotent: installing an already-hosted adapter
    /// overwrites in place, and the registry's placement sets don't
    /// grow duplicates.
    pub fn restore_placements(&mut self) -> Result<usize> {
        let registry = self.cluster.registry().clone();
        let mut restored = 0;
        for id in registry.ids() {
            let servers = registry.servers_for(id);
            if servers.is_empty() {
                continue;
            }
            let spec = self.spec_of(id)?;
            for server in servers {
                self.cluster.install_on(server, &spec)?;
                restored += 1;
            }
        }
        self.stats.initial_placements += restored;
        Ok(restored)
    }

    /// The registry's current view as placement-policy inputs.
    fn placement_inputs(registry: &GlobalRegistry) -> Vec<PlacementInput> {
        registry
            .popularity_table()
            .into_iter()
            .filter_map(|(id, popularity)| {
                registry.get(id).map(|m| PlacementInput {
                    id,
                    rank: m.rank,
                    popularity,
                })
            })
            .collect()
    }

    /// Compute initial placements from the registry (popularity × rank
    /// × slot pressure), install them on the backends, and pre-warm the
    /// `cfg.prewarm` hottest adapters so their first requests admit
    /// warm. Idempotent per adapter (installs overwrite in place), but
    /// intended to run once, before traffic.
    ///
    /// With [`CoordinatorConfig::pool_pages`] set, placement and the
    /// pre-warm set switch to the unified-pool-aware policy instead
    /// (decayed demand, memory-pressure penalty).
    pub fn place_and_prewarm(&mut self) -> Result<()> {
        if let Some(pool) = self.cfg.pool_pages {
            return self.place_and_prewarm_paged(pool);
        }
        let inputs = Self::placement_inputs(self.cluster.registry());
        let placements = placement::compute(
            &inputs,
            &PlacementConfig {
                servers: self.cluster.len(),
                replicas: self.cfg.replicas,
                slots_per_server: self.cfg.slots_per_server,
            },
        );
        for (server, ids) in placements.iter().enumerate() {
            for &id in ids {
                let spec = self.spec_of(id)?;
                self.cluster.install_on(server, &spec)?;
                self.stats.initial_placements += 1;
            }
        }
        for id in placement::top_hot(&inputs, self.cfg.prewarm) {
            for server in self.cluster.registry().servers_for(id) {
                if self.cluster.prewarm_on(server, id)? {
                    self.stats.prewarmed += 1;
                }
            }
        }
        Ok(())
    }

    /// The memory-aware variant of [`Self::place_and_prewarm`]: demand
    /// is the registry's EWMA-decayed popularity (a once-hot adapter
    /// that went quiet yields its residency claim), and the greedy
    /// score charges each adapter's rank-proportional page footprint
    /// against the per-server unified pool, so fat adapters spread
    /// instead of starving one server's KV headroom.
    fn place_and_prewarm_paged(&mut self, pool_pages: usize) -> Result<()> {
        let registry = self.cluster.registry();
        let inputs: Vec<PagedPlacementInput> = registry
            .decayed_table()
            .into_iter()
            .filter_map(|(id, demand)| {
                registry.get(id).map(|m| PagedPlacementInput {
                    id,
                    rank: m.rank,
                    demand,
                    // Exact page counts are runtime-dependent (hidden
                    // size, page geometry); the score only needs the
                    // relative footprint, which is rank-proportional.
                    pages: m.rank.max(1),
                })
            })
            .collect();
        let placements = placement::compute_paged(
            &inputs,
            &PlacementConfig {
                servers: self.cluster.len(),
                replicas: self.cfg.replicas,
                slots_per_server: self.cfg.slots_per_server,
            },
            pool_pages,
        );
        for (server, ids) in placements.iter().enumerate() {
            for &id in ids {
                let spec = self.spec_of(id)?;
                self.cluster.install_on(server, &spec)?;
                self.stats.initial_placements += 1;
            }
        }
        for id in placement::top_hot_paged(&inputs, self.cfg.prewarm) {
            for server in self.cluster.registry().servers_for(id) {
                if self.cluster.prewarm_on(server, id)? {
                    self.stats.prewarmed += 1;
                }
            }
        }
        Ok(())
    }

    /// Rebuild an installable spec from the registry's metadata.
    fn spec_of(&self, id: u64) -> Result<LoraSpec> {
        let meta = self
            .cluster
            .registry()
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("adapter {id} not registered"))?;
        Ok(LoraSpec::standard(id, meta.rank, &meta.base_model))
    }

    /// Load score of one server: queued requests weigh double (they
    /// are pure wait), running ones single, plus decode-growth
    /// preemptions *since the previous tick* (a server shedding load
    /// right now is saturated even when its queue momentarily clears).
    fn load_of(stats: &ServerStats, preempt_delta: usize) -> usize {
        stats.queued_ranks.len() * 2 + stats.running_ranks.len() + preempt_delta
    }

    /// One rebalance pass: retry pending retirements, then — when the
    /// busiest/idlest load gap reaches `min_imbalance` — replicate the
    /// hottest adapter unique to the busiest server onto the idlest,
    /// queueing the source copy for retirement in `Move` mode.
    pub fn tick(&mut self) -> Result<()> {
        self.stats.rebalance_ticks += 1;
        self.try_retire();
        if self.cluster.len() < 2 {
            return Ok(());
        }
        let per_server = self.cluster.per_server_stats();
        self.last_preemptions.resize(per_server.len(), 0);
        let loads: Vec<usize> = per_server
            .iter()
            .zip(&self.last_preemptions)
            .map(|(s, &prev)| Self::load_of(s, s.preemptions.saturating_sub(prev)))
            .collect();
        for (prev, s) in self.last_preemptions.iter_mut().zip(&per_server) {
            *prev = s.preemptions;
        }
        let src = (0..loads.len()).max_by_key(|&s| loads[s]).expect("≥ 2 servers");
        let dst = (0..loads.len()).min_by_key(|&s| loads[s]).expect("≥ 2 servers");
        if src == dst || loads[src] - loads[dst] < self.cfg.min_imbalance {
            return Ok(());
        }
        // The hottest adapter the saturated server hosts that the relief
        // server doesn't — and that isn't already queued to leave `src`.
        let registry = self.cluster.registry();
        let candidate = registry
            .popularity_table()
            .into_iter()
            .filter(|&(_, pop)| pop > 0)
            .map(|(id, _)| id)
            .find(|&id| {
                let servers = registry.servers_for(id);
                servers.contains(&src)
                    && !servers.contains(&dst)
                    && !self.pending_retire.contains(&(id, src))
            });
        let Some(adapter) = candidate else {
            return Ok(());
        };
        let spec = self.spec_of(adapter)?;
        self.cluster.install_on(dst, &spec)?;
        self.stats.migrations += 1;
        self.log.push(MigrationEvent {
            adapter,
            from: src,
            to: dst,
        });
        if self.cfg.mode == MigrationMode::Move {
            self.pending_retire.push((adapter, src));
            self.try_retire();
        }
        Ok(())
    }

    /// Attempt every pending source-copy retirement; copies still
    /// serving in-flight requests stay queued for the next tick.
    fn try_retire(&mut self) {
        let pending = std::mem::take(&mut self.pending_retire);
        for (adapter, server) in pending {
            match self.cluster.uninstall_on(server, adapter) {
                Ok(()) => self.stats.retirements += 1,
                Err(_) => {
                    self.stats.deferred_retirements += 1;
                    self.pending_retire.push((adapter, server));
                }
            }
        }
    }
}

impl ServingFront for Coordinator {
    fn submit(&mut self, req: ServeRequest) -> RequestHandle {
        self.cluster.submit(req)
    }

    /// Advance the cluster one iteration; every `migrate_interval`
    /// polls, run a rebalance tick first — while requests are in
    /// flight, which is exactly when migration matters.
    fn poll(&mut self) -> Result<bool> {
        self.polls += 1;
        if self.cfg.migrate_interval > 0 && self.polls % self.cfg.migrate_interval == 0 {
            self.tick()?;
        }
        self.cluster.poll()
    }

    fn cancel(&mut self, id: u64) -> bool {
        self.cluster.cancel(id)
    }

    fn stats(&self) -> ServerStats {
        self.cluster.stats()
    }

    fn install_adapter(&mut self, spec: &LoraSpec) -> Result<()> {
        self.cluster.install_adapter(spec)
    }

    fn uninstall_adapter(&mut self, adapter: u64) -> Result<()> {
        self.cluster.uninstall_adapter(adapter)
    }

    fn prewarm_adapter(&mut self, adapter: u64) -> Result<bool> {
        self.cluster.prewarm_adapter(adapter)
    }

    fn cold_start_stats(&self) -> Option<ColdStartStats> {
        self.cluster.cold_start_stats()
    }

    /// Cluster-wide install provenance. After a migration whose target
    /// was fed by a streamed artifact push, `synthetic_seeds` on that
    /// backend stays zero — the acceptance signal that weights moved
    /// by digest, not by re-seeding.
    fn install_source_stats(&self) -> InstallSourceStats {
        self.cluster.install_source_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;
    use crate::scheduler::baselines::MostIdle;
    use crate::scheduler::registry::AdapterMeta;
    use crate::server::api::LifecycleState;
    use crate::sim::{GpuModel, ServingMode, SimFront, SimInstance};

    fn sim_backend() -> SimFront {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::CaraServe, 32, 8, 64);
        SimFront::new(inst, 512)
    }

    /// A coordinator over `n` empty sim backends with `adapters`
    /// registered (rank 8) and demand seeded hottest-first (adapter 0
    /// hottest).
    fn coordinator(n: usize, adapters: u64, cfg: CoordinatorConfig) -> Coordinator {
        let registry = Arc::new(GlobalRegistry::new());
        for id in 0..adapters {
            registry.register(AdapterMeta {
                id,
                rank: 8,
                base_model: "sim".into(),
                weights_path: String::new(),
            });
            registry.record_requests(id, (adapters - id) * 4);
        }
        let mut backends: Vec<Box<dyn ServingFront>> = Vec::new();
        for _ in 0..n {
            backends.push(Box::new(sim_backend()));
        }
        Coordinator::new(ClusterFront::new(backends, Box::new(MostIdle), registry), cfg)
    }

    #[test]
    fn place_and_prewarm_installs_and_warms() {
        let mut coord = coordinator(
            2,
            6,
            CoordinatorConfig {
                prewarm: 2,
                ..Default::default()
            },
        );
        coord.place_and_prewarm().unwrap();
        let stats = coord.coordinator_stats().clone();
        assert_eq!(stats.initial_placements, 6);
        assert_eq!(stats.prewarmed, 2);
        // Every adapter is placed exactly once (replicas = 1) and the
        // cluster can serve all of them.
        let registry = coord.cluster().registry().clone();
        for id in 0..6 {
            assert_eq!(registry.servers_for(id).len(), 1, "adapter {id}");
            assert!(coord.stats().can_serve(id));
        }
        // The hottest adapter admits warm (pre-warmed into the sim
        // cache); a cold-tail adapter pays a cold admit.
        let h = coord.submit(ServeRequest::new(0, vec![1; 16]).max_new_tokens(2));
        coord.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
        let cs = coord.cold_start_stats().unwrap();
        assert_eq!(cs.cold_admits, 0, "prewarmed adapter cold-started");
        assert_eq!(cs.warm_admits, 1);
    }

    #[test]
    fn paged_placement_prewarms_by_decayed_demand() {
        let registry = Arc::new(GlobalRegistry::new());
        for id in 0..4 {
            registry.register(AdapterMeta {
                id,
                rank: if id == 0 { 64 } else { 8 },
                base_model: "sim".into(),
                weights_path: String::new(),
            });
        }
        // Adapter 0 was hot long ago; 80 events of adapter-2 traffic
        // age it out; adapter 1 gets a modest recent burst. By raw
        // weight, 0 leads ((10+1)×64 = 704 vs (80+1)×8 = 648); by
        // decayed weight, 2 leads (≈ 69×8 = 553 vs ≈ 2.7×64 = 172).
        registry.record_requests(0, 10);
        registry.record_requests(2, 80);
        registry.record_requests(1, 8);
        let mut backends: Vec<Box<dyn ServingFront>> = Vec::new();
        for _ in 0..2 {
            backends.push(Box::new(sim_backend()));
        }
        let mut coord = Coordinator::new(
            ClusterFront::new(backends, Box::new(MostIdle), registry),
            CoordinatorConfig {
                prewarm: 1,
                pool_pages: Some(64),
                ..Default::default()
            },
        );
        coord.place_and_prewarm().unwrap();
        let stats = coord.coordinator_stats().clone();
        assert_eq!(stats.initial_placements, 4);
        assert_eq!(stats.prewarmed, 1);
        for id in 0..4 {
            assert!(coord.stats().can_serve(id), "adapter {id}");
        }
        // The pre-warmed adapter is the decayed-hottest (2), so its
        // first request admits warm — under the legacy raw-count policy
        // the stale adapter 0 would have taken the prewarm slot.
        let h = coord.submit(ServeRequest::new(2, vec![1; 16]).max_new_tokens(2));
        coord.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
        let cs = coord.cold_start_stats().unwrap();
        assert_eq!(cs.cold_admits, 0, "decayed-hottest adapter cold-started");
        assert_eq!(cs.warm_admits, 1);
    }

    #[test]
    fn migration_replicates_then_retires_after_drain() {
        let mut coord = coordinator(
            2,
            4,
            CoordinatorConfig {
                min_imbalance: 2,
                mode: MigrationMode::Move,
                ..Default::default()
            },
        );
        coord.place_and_prewarm().unwrap();
        let hot = 0u64;
        let src = coord.cluster().registry().servers_for(hot)[0];
        // Pile requests onto the hot adapter without polling: its host
        // saturates while the other server idles.
        let handles: Vec<_> = (0..6)
            .map(|_| coord.submit(ServeRequest::new(hot, vec![1; 16]).max_new_tokens(3)))
            .collect();
        coord.tick().unwrap();
        let stats = coord.coordinator_stats().clone();
        assert_eq!(stats.migrations, 1);
        let ev = coord.migration_log()[0];
        assert_eq!(ev.adapter, hot);
        assert_eq!(ev.from, src);
        // Replicated: both servers host the hot adapter; the source
        // retirement is deferred while its requests are in flight.
        let placed = coord.cluster().registry().servers_for(hot);
        assert_eq!(placed, vec![0, 1]);
        assert!(stats.deferred_retirements >= 1);
        assert_eq!(stats.retirements, 0);
        // Drain, then the next tick completes the move: the source copy
        // retires and the registry placement follows (pruned, no empty
        // tombstone).
        coord.run_until_idle().unwrap();
        coord.tick().unwrap();
        let stats = coord.coordinator_stats().clone();
        assert_eq!(stats.retirements, 1);
        assert_eq!(coord.cluster().registry().servers_for(hot), vec![ev.to]);
        // The in-flight streams were untouched by the migration: the
        // simulator's deterministic 0,1,2 streams arrived complete.
        for h in &handles {
            assert_eq!(h.state(), LifecycleState::Finished);
            assert_eq!(h.tokens(), vec![0, 1, 2]);
        }
    }

    #[test]
    fn balanced_cluster_never_migrates() {
        let mut coord = coordinator(2, 4, CoordinatorConfig::default());
        coord.place_and_prewarm().unwrap();
        for _ in 0..5 {
            coord.tick().unwrap();
        }
        let stats = coord.coordinator_stats();
        assert_eq!(stats.migrations, 0);
        assert_eq!(stats.rebalance_ticks, 5);
    }

    #[test]
    fn replicate_mode_keeps_both_copies() {
        let mut coord = coordinator(
            2,
            4,
            CoordinatorConfig {
                mode: MigrationMode::Replicate,
                min_imbalance: 2,
                ..Default::default()
            },
        );
        coord.place_and_prewarm().unwrap();
        for _ in 0..6 {
            coord.submit(ServeRequest::new(0, vec![1; 16]).max_new_tokens(2));
        }
        coord.tick().unwrap();
        coord.run_until_idle().unwrap();
        coord.tick().unwrap();
        let stats = coord.coordinator_stats();
        assert_eq!(stats.migrations, 1);
        assert_eq!(stats.retirements, 0);
        assert_eq!(coord.cluster().registry().servers_for(0), vec![0, 1]);
    }

    #[test]
    fn crash_restart_restores_placements_and_keeps_migrating() {
        let cfg = CoordinatorConfig {
            min_imbalance: 2,
            ..Default::default()
        };
        let mut coord = coordinator(2, 4, cfg.clone());
        coord.place_and_prewarm().unwrap();
        // Drive a full migration (replicate + drained retirement) so the
        // saved state is not just the initial placement.
        for _ in 0..6 {
            coord.submit(ServeRequest::new(0, vec![1; 16]).max_new_tokens(2));
        }
        coord.tick().unwrap();
        coord.run_until_idle().unwrap();
        coord.tick().unwrap();
        assert_eq!(coord.coordinator_stats().retirements, 1);
        let registry = coord.cluster().registry();
        let before: Vec<(u64, Vec<usize>)> = registry
            .ids()
            .into_iter()
            .map(|id| (id, registry.servers_for(id)))
            .collect();
        let dir = std::env::temp_dir().join("caraserve-coordinator-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("restart_state.json");
        coord.save_state(&path).unwrap();
        drop(coord); // crash: every in-memory structure is gone

        // Restart over fresh, empty backends from the snapshot alone.
        let backends: Vec<Box<dyn ServingFront>> =
            (0..2).map(|_| Box::new(sim_backend()) as Box<dyn ServingFront>).collect();
        let mut coord =
            Coordinator::load_state(&path, backends, Box::new(MostIdle), cfg).unwrap();
        let registry = coord.cluster().registry();
        let after: Vec<(u64, Vec<usize>)> = registry
            .ids()
            .into_iter()
            .map(|id| (id, registry.servers_for(id)))
            .collect();
        assert_eq!(before, after, "restart changed placements");
        // The restored cluster serves every adapter and the migration
        // engine keeps working against the restored demand counters.
        for id in 0..4 {
            assert!(coord.stats().can_serve(id), "adapter {id}");
        }
        for _ in 0..6 {
            coord.submit(ServeRequest::new(1, vec![1; 16]).max_new_tokens(2));
        }
        coord.tick().unwrap();
        assert!(coord.coordinator_stats().migrations >= 1);
        coord.run_until_idle().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn poll_ticks_on_the_configured_interval() {
        let mut coord = coordinator(
            2,
            4,
            CoordinatorConfig {
                migrate_interval: 3,
                ..Default::default()
            },
        );
        coord.place_and_prewarm().unwrap();
        for _ in 0..9 {
            coord.poll().unwrap();
        }
        assert_eq!(coord.coordinator_stats().rebalance_ticks, 3);
        // Interval 0 disables the migration engine entirely.
        let mut frozen = coordinator(
            2,
            4,
            CoordinatorConfig {
                migrate_interval: 0,
                ..Default::default()
            },
        );
        frozen.place_and_prewarm().unwrap();
        for _ in 0..9 {
            frozen.poll().unwrap();
        }
        assert_eq!(frozen.coordinator_stats().rebalance_ticks, 0);
    }
}
