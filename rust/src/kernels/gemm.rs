//! f32 GEMM/GEMV micro-kernels.
//!
//! Row-major, no external BLAS (offline build). The hot path is
//! [`lora_apply`]: y[n,H2] += x[n,H1]·A[H1,r]·B[r,H2] with r ≪ H — the
//! low-rank structure means we materialize the small intermediate
//! t = x·A (n×r) and never form A·B. Loops are ordered ikj so the inner
//! loop is a contiguous AXPY the compiler auto-vectorizes.

/// C[m,n] += A[m,k] · B[k,n]; all row-major slices.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (p, &a_ip) in a_row.iter().enumerate() {
            if a_ip == 0.0 {
                continue;
            }
            let b_row = &b[p * n..(p + 1) * n];
            // AXPY over contiguous memory — auto-vectorizes.
            for (c_v, &b_v) in c_row.iter_mut().zip(b_row) {
                *c_v += a_ip * b_v;
            }
        }
    }
}

/// y[n] += A[m,n]^T-free matvec: y[m] += A[m,n] · x[n].
pub fn gemv(m: usize, n: usize, a: &[f32], x: &[f32], y: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        let mut acc = 0.0f32;
        for (a_v, x_v) in row.iter().zip(x) {
            acc += a_v * x_v;
        }
        y[i] += acc;
    }
}

/// LoRA adaptation for a block of tokens:
/// `y[n_tok, h2] += (x[n_tok, h1] · A[h1, r]) · B[r, h2]`.
///
/// `scratch` must have room for `n_tok * r` f32s (the t = x·A
/// intermediate); it is overwritten. Keeping the scratch caller-owned
/// avoids per-invocation allocation on the layer-synchronous hot path.
pub fn lora_apply(
    n_tok: usize,
    h1: usize,
    h2: usize,
    r: usize,
    x: &[f32],
    a: &[f32],
    b: &[f32],
    y: &mut [f32],
    scratch: &mut [f32],
) {
    assert_eq!(x.len(), n_tok * h1, "x shape");
    assert_eq!(a.len(), h1 * r, "A shape");
    assert_eq!(b.len(), r * h2, "B shape");
    assert_eq!(y.len(), n_tok * h2, "y shape");
    assert!(scratch.len() >= n_tok * r, "scratch too small");
    let t = &mut scratch[..n_tok * r];
    t.fill(0.0);
    gemm(n_tok, h1, r, x, a, t);
    gemm(n_tok, r, h2, t, b, y);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (16, 64, 8), (8, 128, 128)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let want = naive_gemm(m, k, n, &a, &b);
            let mut got = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn gemm_accumulates_into_c() {
        let a = vec![1.0f32; 4]; // 2x2 ones
        let b = vec![1.0f32; 4];
        let mut c = vec![10.0f32; 4];
        gemm(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, vec![12.0; 4]);
    }

    #[test]
    fn gemv_matches_gemm() {
        let mut rng = Rng::new(2);
        let (m, n) = (17, 33);
        let a = rand_vec(&mut rng, m * n);
        let x = rand_vec(&mut rng, n);
        let mut y1 = vec![0.0f32; m];
        gemv(m, n, &a, &x, &mut y1);
        let want = naive_gemm(m, n, 1, &a, &x);
        for (g, w) in y1.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn lora_apply_equals_explicit_ab() {
        let mut rng = Rng::new(3);
        let (n_tok, h1, h2, r) = (5, 32, 32, 4);
        let x = rand_vec(&mut rng, n_tok * h1);
        let a = rand_vec(&mut rng, h1 * r);
        let b = rand_vec(&mut rng, r * h2);
        // want = x · (A·B)
        let ab = naive_gemm(h1, r, h2, &a, &b);
        let want = naive_gemm(n_tok, h1, h2, &x, &ab);
        let mut y = vec![0.0f32; n_tok * h2];
        let mut scratch = vec![0.0f32; n_tok * r];
        lora_apply(n_tok, h1, h2, r, &x, &a, &b, &mut y, &mut scratch);
        for (g, w) in y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
    }

    #[test]
    #[should_panic(expected = "scratch")]
    fn lora_apply_checks_scratch() {
        let mut y = vec![0.0f32; 4];
        let mut scratch = vec![0.0f32; 1];
        lora_apply(
            2,
            2,
            2,
            2,
            &[0.0; 4],
            &[0.0; 4],
            &[0.0; 4],
            &mut y,
            &mut scratch,
        );
    }
}
