//! CPU compute kernels for LoRA adaptation.
//!
//! These are the Rust twins of the Pallas L1 kernels: the CPU-assisted
//! LoRA engine ([`crate::cpu_lora`]) runs these on host cores during the
//! cold-start window, with semantics identical to `python/compile/
//! kernels/bgmv.py` (checked by the cross-validation integration test).

pub mod bgmv;
pub mod gemm;

pub use bgmv::{bgmv_padded, mbgmv, mbgmv_ref, sgmv_grouped, AdapterWeights};
pub use gemm::{gemm, gemv, lora_apply};
