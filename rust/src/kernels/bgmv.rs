//! Batched-Gather Matrix-Vector kernels: the CPU twins of Punica's BGMV
//! and S-LoRA's MBGMV (paper §2.3 / §4.1).
//!
//! Semantics, matching the CUDA originals and the L1 Pallas kernels:
//! a batch of tokens, each mapped by `indices[i]` to one adapter;
//! `y[i] += x[i] · A[idx] · B[idx]`.
//!
//! - **BGMV** ([`bgmv_padded`]): every adapter is *padded* to the max rank
//!   in the adapter set, so the work per token is `O(H · max_rank)` —
//!   this is why Punica's latency tracks `|S| · max_rank` (Fig 4-Left).
//! - **MBGMV** ([`mbgmv`]): no padding; each token does `O(H · r_idx)`
//!   work, so batch latency tracks `Σ rank` (Fig 4-Right).

use super::gemm::lora_apply;

/// Weights of one adapter for one target matrix: A (h1×r) and B (r×h2),
/// row-major f32. `rank` is the true (unpadded) rank.
#[derive(Debug, Clone)]
pub struct AdapterWeights {
    pub rank: usize,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub h1: usize,
    pub h2: usize,
}

impl AdapterWeights {
    /// Deterministic pseudo-random weights (the paper uses dummy weights;
    /// the values don't matter for system behaviour, but they must be
    /// reproducible for the Rust↔Pallas cross-check).
    pub fn synthetic(seed: u64, h1: usize, h2: usize, rank: usize) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let scale = 1.0 / (rank as f32).sqrt();
        let a = (0..h1 * rank)
            .map(|_| (rng.f32() * 2.0 - 1.0) * scale)
            .collect();
        let b = (0..rank * h2)
            .map(|_| (rng.f32() * 2.0 - 1.0) * scale)
            .collect();
        Self { rank, a, b, h1, h2 }
    }

    /// Zero-pad this adapter's A/B out to `max_rank` (what BGMV does on
    /// device). Padding columns of A and rows of B are zero, so results
    /// are unchanged while the compute cost grows to `max_rank`.
    pub fn padded_to(&self, max_rank: usize) -> AdapterWeights {
        assert!(max_rank >= self.rank);
        let mut a = vec![0.0f32; self.h1 * max_rank];
        for row in 0..self.h1 {
            a[row * max_rank..row * max_rank + self.rank]
                .copy_from_slice(&self.a[row * self.rank..(row + 1) * self.rank]);
        }
        let mut b = vec![0.0f32; max_rank * self.h2];
        b[..self.rank * self.h2].copy_from_slice(&self.b);
        AdapterWeights {
            rank: max_rank,
            a,
            b,
            h1: self.h1,
            h2: self.h2,
        }
    }

    /// Weight bytes (f32 here; fp16 on the modeled GPU).
    pub fn len_bytes(&self) -> usize {
        (self.a.len() + self.b.len()) * 4
    }
}

/// Padded BGMV: `y[i] += x[i] · A[idx_i] · B[idx_i]` where all adapters
/// have been padded to a common `max_rank`. Each token performs
/// `O(h1·max_rank + max_rank·h2)` work regardless of its true rank —
/// faithfully reproducing Punica's cost model.
pub fn bgmv_padded(
    adapters: &[AdapterWeights],
    indices: &[usize],
    h1: usize,
    h2: usize,
    x: &[f32],
    y: &mut [f32],
) {
    let n = indices.len();
    assert_eq!(x.len(), n * h1);
    assert_eq!(y.len(), n * h2);
    let max_rank = adapters.iter().map(|a| a.rank).max().unwrap_or(0);
    if max_rank == 0 || n == 0 {
        return;
    }
    // Pad each distinct adapter once (the device keeps them padded).
    let padded: Vec<AdapterWeights> =
        adapters.iter().map(|a| a.padded_to(max_rank)).collect();
    let mut scratch = vec![0.0f32; max_rank];
    for (i, &idx) in indices.iter().enumerate() {
        let ad = &padded[idx];
        assert_eq!(ad.h1, h1);
        assert_eq!(ad.h2, h2);
        lora_apply(
            1,
            h1,
            h2,
            max_rank,
            &x[i * h1..(i + 1) * h1],
            &ad.a,
            &ad.b,
            &mut y[i * h2..(i + 1) * h2],
            &mut scratch,
        );
    }
}

/// MBGMV: padding-free multi-size BGMV. Each token does work proportional
/// to its *own* adapter's rank — reproducing S-LoRA's Σrank cost model.
pub fn mbgmv(
    adapters: &[AdapterWeights],
    indices: &[usize],
    h1: usize,
    h2: usize,
    x: &[f32],
    y: &mut [f32],
) {
    let refs: Vec<&AdapterWeights> = adapters.iter().collect();
    mbgmv_ref(&refs, indices, h1, h2, x, y);
}

/// Rank-grouped SGMV (S-LoRA §5 / CaraServe §4.3 decode path): tokens
/// that share an adapter — same weights, same rank — are batched through
/// **one** [`lora_apply`] call per consecutive run, instead of one
/// gather + kernel launch per token. A decode batch routed to a handful
/// of adapters collapses from `n` rank-r matvecs into a few rank-r
/// GEMMs over contiguous token blocks; a prefill (all tokens one
/// adapter) becomes a single call.
///
/// Bitwise-identical to [`mbgmv_ref`]: `lora_apply` computes each token
/// row independently (`gemm` iterates rows), so grouping changes the
/// call count, never the per-row arithmetic — the property that lets
/// the resident decode path adopt this kernel without perturbing token
/// streams (pinned by `sgmv_grouped_is_bitwise_mbgmv`).
///
/// `scratch` is resized to the largest group's `n_tok·rank` floats and
/// reused across groups — no per-token allocation.
pub fn sgmv_grouped(
    adapters: &[&AdapterWeights],
    indices: &[usize],
    h1: usize,
    h2: usize,
    x: &[f32],
    y: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    let n = indices.len();
    assert_eq!(x.len(), n * h1);
    assert_eq!(y.len(), n * h2);
    let mut start = 0usize;
    while start < n {
        let idx = indices[start];
        let mut end = start + 1;
        while end < n && indices[end] == idx {
            end += 1;
        }
        let ad = adapters[idx];
        assert_eq!(ad.h1, h1);
        assert_eq!(ad.h2, h2);
        let group = end - start;
        if scratch.len() < group * ad.rank {
            scratch.resize(group * ad.rank, 0.0);
        }
        lora_apply(
            group,
            h1,
            h2,
            ad.rank,
            &x[start * h1..end * h1],
            &ad.a,
            &ad.b,
            &mut y[start * h2..end * h2],
            scratch,
        );
        start = end;
    }
}

/// [`mbgmv`] over *borrowed* adapter stacks — the device-resident path of
/// the serving engine gathers each slot's stack without cloning weights
/// (the stacks live behind `Arc`s shared with the CPU-LoRA workers, which
/// is what makes the CPU-assisted and resident outputs bit-compatible).
pub fn mbgmv_ref(
    adapters: &[&AdapterWeights],
    indices: &[usize],
    h1: usize,
    h2: usize,
    x: &[f32],
    y: &mut [f32],
) {
    let n = indices.len();
    assert_eq!(x.len(), n * h1);
    assert_eq!(y.len(), n * h2);
    let max_rank = adapters.iter().map(|a| a.rank).max().unwrap_or(0);
    let mut scratch = vec![0.0f32; max_rank.max(1)];
    for (i, &idx) in indices.iter().enumerate() {
        let ad = adapters[idx];
        assert_eq!(ad.h1, h1);
        assert_eq!(ad.h2, h2);
        lora_apply(
            1,
            h1,
            h2,
            ad.rank,
            &x[i * h1..(i + 1) * h1],
            &ad.a,
            &ad.b,
            &mut y[i * h2..(i + 1) * h2],
            &mut scratch,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn padding_preserves_results() {
        let ad = AdapterWeights::synthetic(7, 16, 16, 4);
        let padded = ad.padded_to(16);
        let mut rng = Rng::new(1);
        let x = rand_vec(&mut rng, 16);
        let mut y1 = vec![0.0f32; 16];
        let mut y2 = vec![0.0f32; 16];
        let mut s = vec![0.0f32; 16];
        lora_apply(1, 16, 16, 4, &x, &ad.a, &ad.b, &mut y1, &mut s);
        lora_apply(1, 16, 16, 16, &x, &padded.a, &padded.b, &mut y2, &mut s);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn bgmv_equals_mbgmv_numerically() {
        // Padding changes cost, not results: both kernels must agree.
        let h = 32;
        let adapters: Vec<AdapterWeights> = [2usize, 4, 8]
            .iter()
            .enumerate()
            .map(|(i, &r)| AdapterWeights::synthetic(i as u64, h, h, r))
            .collect();
        let indices = [0usize, 1, 2, 1, 0, 2, 2];
        let mut rng = Rng::new(9);
        let x = rand_vec(&mut rng, indices.len() * h);
        let mut y1 = vec![0.0f32; indices.len() * h];
        let mut y2 = vec![0.0f32; indices.len() * h];
        bgmv_padded(&adapters, &indices, h, h, &x, &mut y1);
        mbgmv(&adapters, &indices, h, h, &x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn gather_picks_the_right_adapter() {
        // Two adapters with very different B matrices; check each token's
        // output reflects its own adapter.
        let h = 8;
        let mut a0 = AdapterWeights::synthetic(0, h, h, 1);
        let mut a1 = AdapterWeights::synthetic(1, h, h, 1);
        a0.a.fill(1.0);
        a0.b.fill(1.0); // output = sum(x) in every column
        a1.a.fill(1.0);
        a1.b.fill(-1.0); // output = -sum(x)
        let x = vec![1.0f32; 2 * h]; // sum = 8 per token
        let mut y = vec![0.0f32; 2 * h];
        mbgmv(&[a0, a1], &[0, 1], h, h, &x, &mut y);
        assert!(y[..h].iter().all(|&v| (v - 8.0).abs() < 1e-5));
        assert!(y[h..].iter().all(|&v| (v + 8.0).abs() < 1e-5));
    }

    #[test]
    fn sgmv_grouped_is_bitwise_mbgmv() {
        // Grouping same-adapter runs must not change a single bit: the
        // resident decode path swaps mbgmv_ref for sgmv_grouped on the
        // strength of this equivalence.
        let h = 32;
        let adapters: Vec<AdapterWeights> = [2usize, 4, 8, 4]
            .iter()
            .enumerate()
            .map(|(i, &r)| AdapterWeights::synthetic(i as u64, h, h, r))
            .collect();
        let refs: Vec<&AdapterWeights> = adapters.iter().collect();
        // Mixed runs: single tokens, long same-adapter stretches, and a
        // same-rank-different-adapter boundary (2 vs 3).
        let indices = [0usize, 1, 1, 1, 2, 2, 3, 1, 0, 0, 0, 0];
        let mut rng = Rng::new(11);
        let x = rand_vec(&mut rng, indices.len() * h);
        let mut y_ref = vec![0.25f32; indices.len() * h];
        let mut y_grp = y_ref.clone();
        mbgmv_ref(&refs, &indices, h, h, &x, &mut y_ref);
        let mut scratch = Vec::new();
        sgmv_grouped(&refs, &indices, h, h, &x, &mut y_grp, &mut scratch);
        assert_eq!(y_ref, y_grp, "grouped kernel diverged bitwise");
    }

    #[test]
    fn sgmv_grouped_single_adapter_is_one_group() {
        // All-one-adapter (the prefill shape): one lora_apply over the
        // whole block still matches the per-token reference.
        let h = 16;
        let ad = AdapterWeights::synthetic(5, h, h, 4);
        let n = 9;
        let indices = vec![0usize; n];
        let mut rng = Rng::new(3);
        let x = rand_vec(&mut rng, n * h);
        let mut y_ref = vec![0.0f32; n * h];
        let mut y_grp = vec![0.0f32; n * h];
        mbgmv_ref(&[&ad], &indices, h, h, &x, &mut y_ref);
        let mut scratch = Vec::new();
        sgmv_grouped(&[&ad], &indices, h, h, &x, &mut y_grp, &mut scratch);
        assert_eq!(y_ref, y_grp);
        assert!(scratch.len() >= n * 4, "scratch sized for the full group");
    }

    #[test]
    fn empty_batch_is_noop() {
        let adapters = vec![AdapterWeights::synthetic(0, 4, 4, 2)];
        let mut y: Vec<f32> = vec![];
        bgmv_padded(&adapters, &[], 4, 4, &[], &mut y);
        mbgmv(&adapters, &[], 4, 4, &[], &mut y);
    }

    #[test]
    fn accumulates_into_y() {
        let h = 4;
        let mut ad = AdapterWeights::synthetic(0, h, h, 1);
        ad.a.fill(0.0);
        ad.b.fill(0.0);
        let x = vec![1.0f32; h];
        let mut y = vec![5.0f32; h];
        mbgmv(&[ad], &[0], h, h, &x, &mut y);
        assert_eq!(y, vec![5.0; h]); // zero adapter leaves y unchanged
    }
}
