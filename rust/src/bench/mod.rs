//! Mini-criterion: a self-contained benchmark harness (the offline vendor
//! set has no `criterion`). Every `benches/*.rs` target uses this.
//!
//! Two kinds of benchmarks coexist in this repo:
//!
//! 1. **Wall-clock micro/meso benchmarks** ([`Bencher`]): warmup, then
//!    timed iterations, reporting mean/p50/p99 like criterion.
//! 2. **Experiment reproductions** ([`Report`]): benches that re-run a
//!    paper experiment (usually on the discrete-event simulator) and
//!    print the figure's rows/series as aligned tables, with a JSON dump
//!    for machine consumption.

use std::time::{Duration, Instant};

use crate::util::json::{self, Json};
use crate::util::stats::Summary;

/// One wall-clock benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    fn from_samples(name: &str, samples_ns: &[f64]) -> BenchResult {
        let s = Summary::of(samples_ns).expect("no samples");
        let d = |ns: f64| Duration::from_nanos(ns.max(0.0) as u64);
        BenchResult {
            name: name.to_string(),
            iters: s.count,
            mean: d(s.mean),
            p50: d(s.p50),
            p99: d(s.p99),
            min: d(s.min),
            max: d(s.max),
        }
    }
}

/// Wall-clock bencher with warmup + adaptive iteration count.
pub struct Bencher {
    /// Target measurement time per benchmark.
    pub measure_time: Duration,
    /// Warmup time before measurement.
    pub warmup_time: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Default: 0.5 s warmup, 2 s measurement (overridable via
    /// `CARA_BENCH_FAST=1` for CI, which cuts both to ~100 ms).
    pub fn new() -> Self {
        let fast = std::env::var("CARA_BENCH_FAST").is_ok();
        Self {
            measure_time: if fast {
                Duration::from_millis(100)
            } else {
                Duration::from_secs(2)
            },
            warmup_time: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(500)
            },
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }

    /// Run one benchmark: `f` is called once per iteration; its return
    /// value is black-boxed to prevent dead-code elimination.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup.
        let warm_start = Instant::now();
        while warm_start.elapsed() < self.warmup_time {
            black_box(f());
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure_time && samples_ns.len() < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let result = BenchResult::from_samples(name, &samples_ns);
        println!(
            "{:<48} {:>12} {:>12} {:>12}  ({} iters)",
            result.name,
            fmt_dur(result.mean),
            fmt_dur(result.p50),
            fmt_dur(result.p99),
            result.iters
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print the header row for bench output.
    pub fn header(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<48} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "p50", "p99"
        );
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Opaque-value hint against dead-code elimination (stable-Rust version of
/// `std::hint::black_box`, which is available and used directly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Format a duration with adaptive units.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// A figure/table reproduction report: named columns, rows of cells, and
/// free-form notes; renders as an aligned text table plus optional JSON.
#[derive(Debug, Clone)]
pub struct Report {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Report {
    /// New report with column headers.
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append a row (must match the column count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Append a free-form note printed under the table.
    pub fn note(&mut self, text: impl Into<String>) {
        self.notes.push(text.into());
    }

    /// Render as an aligned table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let hdr: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect();
        out.push_str(&hdr.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(hdr.join("  ").len()));
        out.push('\n');
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            out.push_str(&line.join("  "));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// JSON form for machine consumption / EXPERIMENTS.md regeneration.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("title", json::s(&self.title)),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| json::s(c)).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| json::s(c)).collect()))
                        .collect(),
                ),
            ),
            (
                "notes",
                Json::Arr(self.notes.iter().map(|n| json::s(n)).collect()),
            ),
        ])
    }

    /// Write the JSON form under `target/bench-reports/<slug>.json`.
    pub fn save(&self, slug: &str) -> std::io::Result<()> {
        let dir = std::path::Path::new("target/bench-reports");
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{slug}.json")), self.to_json().to_string_pretty())
    }
}

/// Format a float cell with fixed precision.
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Format a millisecond cell from seconds.
pub fn ms(seconds: f64) -> String {
    format!("{:.2}", seconds * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_measures_something() {
        std::env::set_var("CARA_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let r = b.bench("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters > 10);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.min <= r.p50 && r.p50 <= r.max);
    }

    #[test]
    fn report_renders_and_serializes() {
        let mut rep = Report::new("Fig X", &["rps", "ttft_ms"]);
        rep.row(vec!["3".into(), "12.5".into()]);
        rep.row(vec!["9".into(), "40.1".into()]);
        rep.note("shape matches paper");
        let text = rep.render();
        assert!(text.contains("Fig X"));
        assert!(text.contains("40.1"));
        let j = rep.to_json();
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn report_arity_checked() {
        let mut rep = Report::new("t", &["a", "b"]);
        rep.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
