//! The distributed serving tier: CaraServe's request plane split
//! across OS processes.
//!
//! Everything below the [`crate::server::ServingFront`] trait —
//! engines, simulators, the rank-aware `ClusterFront`, the §3
//! coordinator — was built process-local. This module is the transport
//! that carries that exact trait surface between processes, so the
//! router composes remote backends with **unchanged** routing,
//! failover, and placement code:
//!
//! - [`wire`] — the length-prefixed, versioned frame protocol: the
//!   full `ServingFront` surface (submit / poll-events / cancel /
//!   stats / install / uninstall / prewarm / cold-start stats) plus
//!   handshake and heartbeat frames, encoded dependency-free and
//!   decoded with typed errors, never panics.
//! - [`server`] — the backend host: wraps any `ServingFront` and
//!   serves the protocol from a Unix-socket listener loop
//!   (`caraserve backend` runs one per process).
//! - [`client`] — [`client::RemoteFront`], the `ServingFront` proxy
//!   the router holds; replays remote events into ordinary local
//!   [`crate::server::RequestHandle`]s and reconnects-with-state after
//!   transport failures (distinguished from failover: a rejoining
//!   backend re-handshakes and reports its resident adapters, so the
//!   router readmits it without re-install when state survived).
//! - [`http`] — the HTTP/1.1 JSON front door over
//!   `std::net::TcpListener`: `POST /v1/requests` streams token events
//!   as chunked JSON lines, `DELETE` cancels, `GET /v1/stats` reports,
//!   and [`http::soak`] is the concurrent-streaming load oracle.
//!
//! The wire also carries the [`crate::artifacts`] transfer plane:
//! manifest fetch and chunked, digest-verified blob push/pull frames,
//! so installs and migrations stream real weights between processes
//! (client [`client::PushSession`] ↔ a store attached to the host via
//! [`server::serve_listener_with_store`]). Per-chunk digests catch
//! corruption at the chunk that carried it; content addressing dedups
//! blobs already present on the receiving side.

pub mod client;
pub mod http;
pub mod server;
pub mod wire;

pub use client::{PushSession, RemoteError, RemoteFront, DEFAULT_CHUNK_BYTES};
pub use http::{soak, HttpGateway, SoakReport};
pub use server::{
    bind, serve_connection, serve_connection_with_store, serve_listener,
    serve_listener_with_store, ConnExit,
};
