//! The versioned frame codec of the distributed serving tier.
//!
//! Every message between a router-side [`crate::remote::RemoteFront`]
//! and a backend host ([`crate::remote::server`]) is one byte frame
//! (shipped via [`crate::ipc::SocketChannel::send_bytes`]) holding:
//!
//! ```text
//! [magic u16 LE][version u16 LE][tag u8][payload ...]
//! ```
//!
//! The payload is a hand-rolled little-endian encoding of the [`Frame`]
//! variant's fields — the full [`crate::server::ServingFront`] surface
//! (submit / poll-events / cancel / stats / install / uninstall /
//! prewarm / cold-start counters) plus the handshake and heartbeat
//! frames the reconnect-with-state protocol needs.
//!
//! **Decode never panics.** Corrupt, truncated, oversized, or
//! wrong-version frames surface as a typed [`WireError`]; every length
//! is validated against the bytes actually present before any
//! allocation, and the recursive [`RejectReason`] decoder is
//! depth-bounded. The `caraserve lint` `wire-panic-free` rule holds
//! this file to that contract textually (no `unwrap`/`expect`/`panic!`/
//! asserts outside tests), and `rust/tests/prop_wire.rs` holds it to it
//! behaviorally (round-trip + mutation property tests).

use crate::model::{LoraSpec, TargetMatrix};
use crate::scheduler::{AdapterSet, ServerStats};
use crate::server::api::{
    FinishReason, Priority, RejectReason, RequestEvent, ResumeState, SamplingParams, ServeRequest,
    SloSpec,
};
use crate::server::metrics::ColdStartStats;

/// Frame preamble: "CaraSErve" — a cheap guard against a desynchronized
/// or foreign byte stream being interpreted as a frame.
pub const MAGIC: u16 = 0xCA5E;

/// Protocol version carried by every frame. Peers refuse frames from a
/// different version with [`WireError::UnknownVersion`] instead of
/// misparsing them.
pub const VERSION: u16 = 1;

/// Maximum [`RejectReason`] nesting the decoder will follow
/// (`NoEligibleServer { last }` is recursive). Honest encoders produce
/// depth ≤ 2; the bound turns a malicious deep frame into a typed error
/// instead of a stack overflow.
const MAX_REASON_DEPTH: u8 = 8;

/// Hard cap on one artifact chunk's byte payload (1 MiB). The decoder
/// refuses a larger declared length with [`WireError::ChunkTooLarge`]
/// *before* allocating — a hostile length prefix can therefore never
/// stage more than this per chunk, independent of how large the
/// enclosing socket frame is allowed to be. Senders honor the same
/// constant, so honest transfers never trip it.
pub const MAX_CHUNK_BYTES: usize = 1 << 20;

/// Typed decode failure. Every variant is a *protocol* outcome the
/// caller can branch on — nothing in this module panics on wire data.
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// The frame ended before a declared field: `need` more bytes were
    /// required, `have` remained.
    Truncated { need: usize, have: usize },
    /// The first two bytes are not [`MAGIC`].
    BadMagic { got: u16 },
    /// The frame's version word differs from [`VERSION`].
    UnknownVersion { got: u16 },
    /// The frame tag (or a nested enum discriminant) is not one this
    /// version defines.
    UnknownTag { tag: u8, context: &'static str },
    /// A declared element count implies more bytes than the frame
    /// carries (or overflows) — refused before allocation.
    Oversized { declared: usize, have: usize },
    /// A field held a value outside its domain (bad bool byte, usize
    /// overflow, reason nesting past [`MAX_REASON_DEPTH`]).
    BadValue { what: &'static str, got: u64 },
    /// A string field was not valid UTF-8.
    BadString,
    /// Bytes remained after a complete frame was decoded.
    Trailing { extra: usize },
    /// An artifact chunk declared a payload larger than
    /// [`MAX_CHUNK_BYTES`] — refused before allocation.
    ChunkTooLarge { declared: usize, max: usize },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "frame truncated: need {need} more bytes, have {have}")
            }
            WireError::BadMagic { got } => write!(f, "bad frame magic {got:#06x}"),
            WireError::UnknownVersion { got } => {
                write!(f, "unknown protocol version {got} (speaking {VERSION})")
            }
            WireError::UnknownTag { tag, context } => {
                write!(f, "unknown {context} tag {tag}")
            }
            WireError::Oversized { declared, have } => {
                write!(f, "declared length {declared} exceeds frame ({have} bytes left)")
            }
            WireError::BadValue { what, got } => write!(f, "bad {what} value {got}"),
            WireError::BadString => write!(f, "string field is not valid UTF-8"),
            WireError::Trailing { extra } => write!(f, "{extra} trailing bytes after frame"),
            WireError::ChunkTooLarge { declared, max } => {
                write!(f, "chunk payload of {declared} bytes exceeds cap {max}")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// One protocol message. Client→server requests first, server→client
/// replies second; the protocol is strict request-reply (every client
/// frame gets exactly one reply), so a variant's direction is fixed by
/// construction even though the codec is shared.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    // ---- client → server ------------------------------------------------
    /// Handshake opener; `client` names the router for logs.
    Hello { client: String },
    /// Submit a request under the router-chosen `client_id` (the id the
    /// events for this request will carry back).
    Submit { client_id: u64, req: ServeRequest },
    /// Advance the backend one iteration and drain pending events.
    Poll,
    /// Cancel the request submitted as `client_id`.
    Cancel { client_id: u64 },
    /// Fetch the backend's [`ServerStats`].
    Stats,
    /// Install an adapter (the coordinator's management surface).
    Install { spec: LoraSpec },
    /// Uninstall an adapter.
    Uninstall { adapter: u64 },
    /// Pre-warm an installed adapter.
    Prewarm { adapter: u64 },
    /// Fetch cold-start counters.
    ColdStart,
    /// Liveness probe; the reply echoes `nonce`.
    Heartbeat { nonce: u64 },
    /// Ask the backend host process to exit its listener loop.
    Shutdown,
    /// Artifact pipeline: ask for the manifest of `adapter` from the
    /// backend's attached [`crate::artifacts::ArtifactStore`].
    FetchManifest { adapter: u64 },
    /// Artifact pipeline: ask for `len` bytes of blob `digest` starting
    /// at `offset`. `len` is capped at [`MAX_CHUNK_BYTES`] on decode.
    FetchChunk {
        digest: String,
        offset: u64,
        len: u32,
    },
    /// Artifact pipeline: install a manifest document (canonical JSON
    /// text + its digest) into the backend's store. Sent *after* every
    /// blob it references has been pushed; the backend verifies text
    /// against digest and blobs against the manifest before indexing.
    PushManifest { json: String, digest: String },
    /// Artifact pipeline: one streamed chunk of blob `digest`.
    /// `chunk_digest` is the SHA-256 of `bytes` alone (per-chunk
    /// integrity + progress), `total` the full blob size; the backend
    /// commits only after the assembled bytes hash to `digest`.
    PushChunk {
        digest: String,
        offset: u64,
        total: u64,
        bytes: Vec<u8>,
        chunk_digest: String,
    },
    /// Artifact pipeline: fetch the backend's install-source counters
    /// (how many installs were served from the store vs synthetically
    /// seeded) — the migration acceptance probe.
    ArtifactStat,

    // ---- server → client ------------------------------------------------
    /// Handshake reply: the backend's protocol version, display name,
    /// and — the reconnect-with-state payload — the adapter set still
    /// resident from before the connection was lost.
    Welcome {
        version: u16,
        server: String,
        resident: AdapterSet,
    },
    /// Submit reply; `backend_id` is the backend-local request id and
    /// `events` are the lifecycle events the submission produced
    /// *synchronously* (`Admitted`, or a terminal `Rejected`) — carried
    /// here so a backend's synchronous admission refusal is visible to
    /// the router's re-route loop immediately, exactly as in-process.
    Submitted {
        client_id: u64,
        backend_id: u64,
        events: Vec<RequestEvent>,
    },
    /// Poll reply: undelivered events per client request id, plus the
    /// backend's `poll()` progress flag.
    Events {
        events: Vec<(u64, RequestEvent)>,
        progressed: bool,
    },
    /// Cancel reply: was the request still live?
    CancelResult { live: bool },
    /// Stats reply.
    StatsReply { stats: ServerStats },
    /// Prewarm reply: did the backend warm it?
    PrewarmResult { warmed: bool },
    /// Cold-start counters reply (`None` when the backend tracks none).
    ColdStartReply { stats: Option<ColdStartStats> },
    /// Heartbeat reply.
    HeartbeatAck { nonce: u64 },
    /// Generic success reply (install / uninstall / shutdown).
    OkReply,
    /// Generic failure reply; `message` is the backend error rendered.
    ErrReply { message: String },
    /// [`Frame::FetchManifest`] reply. `found: false` (with empty
    /// `json`/`digest`) means the store has no manifest for the adapter
    /// — a protocol outcome, not an error.
    ManifestReply {
        found: bool,
        json: String,
        digest: String,
    },
    /// [`Frame::FetchChunk`] reply: the requested slice (possibly
    /// shorter at end-of-blob), the blob's `total` size, and the
    /// per-chunk digest of `bytes`.
    ChunkReply {
        digest: String,
        offset: u64,
        total: u64,
        bytes: Vec<u8>,
        chunk_digest: String,
    },
    /// [`Frame::PushChunk`] reply: `have` bytes staged (or committed)
    /// so far; `complete` once the blob is verified and stored.
    PushAck { complete: bool, have: u64 },
    /// [`Frame::ArtifactStat`] reply.
    ArtifactStatReply {
        store_hits: u64,
        synthetic_seeds: u64,
        blobs: u64,
    },
}

// Frame tags. Client requests are 1.., replies 64.. — disjoint ranges
// so a misdirected frame decodes to an unmistakably wrong variant
// rather than a plausible one.
const TAG_HELLO: u8 = 1;
const TAG_SUBMIT: u8 = 2;
const TAG_POLL: u8 = 3;
const TAG_CANCEL: u8 = 4;
const TAG_STATS: u8 = 5;
const TAG_INSTALL: u8 = 6;
const TAG_UNINSTALL: u8 = 7;
const TAG_PREWARM: u8 = 8;
const TAG_COLD_START: u8 = 9;
const TAG_HEARTBEAT: u8 = 10;
const TAG_SHUTDOWN: u8 = 11;
const TAG_FETCH_MANIFEST: u8 = 12;
const TAG_FETCH_CHUNK: u8 = 13;
const TAG_PUSH_MANIFEST: u8 = 14;
const TAG_PUSH_CHUNK: u8 = 15;
const TAG_ARTIFACT_STAT: u8 = 16;
const TAG_WELCOME: u8 = 64;
const TAG_SUBMITTED: u8 = 65;
const TAG_EVENTS: u8 = 66;
const TAG_CANCEL_RESULT: u8 = 67;
const TAG_STATS_REPLY: u8 = 68;
const TAG_PREWARM_RESULT: u8 = 69;
const TAG_COLD_START_REPLY: u8 = 70;
const TAG_HEARTBEAT_ACK: u8 = 71;
const TAG_OK: u8 = 72;
const TAG_ERR: u8 = 73;
const TAG_MANIFEST_REPLY: u8 = 74;
const TAG_CHUNK_REPLY: u8 = 75;
const TAG_PUSH_ACK: u8 = 76;
const TAG_ARTIFACT_STAT_REPLY: u8 = 77;

/// Encode one frame to bytes (header + payload). Encoding is total —
/// it cannot fail and never panics.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut w = Writer::new();
    w.u16(MAGIC);
    w.u16(VERSION);
    match frame {
        Frame::Hello { client } => {
            w.u8(TAG_HELLO);
            w.string(client);
        }
        Frame::Submit { client_id, req } => {
            w.u8(TAG_SUBMIT);
            w.u64(*client_id);
            put_request(&mut w, req);
        }
        Frame::Poll => w.u8(TAG_POLL),
        Frame::Cancel { client_id } => {
            w.u8(TAG_CANCEL);
            w.u64(*client_id);
        }
        Frame::Stats => w.u8(TAG_STATS),
        Frame::Install { spec } => {
            w.u8(TAG_INSTALL);
            put_spec(&mut w, spec);
        }
        Frame::Uninstall { adapter } => {
            w.u8(TAG_UNINSTALL);
            w.u64(*adapter);
        }
        Frame::Prewarm { adapter } => {
            w.u8(TAG_PREWARM);
            w.u64(*adapter);
        }
        Frame::ColdStart => w.u8(TAG_COLD_START),
        Frame::Heartbeat { nonce } => {
            w.u8(TAG_HEARTBEAT);
            w.u64(*nonce);
        }
        Frame::Shutdown => w.u8(TAG_SHUTDOWN),
        Frame::FetchManifest { adapter } => {
            w.u8(TAG_FETCH_MANIFEST);
            w.u64(*adapter);
        }
        Frame::FetchChunk {
            digest,
            offset,
            len,
        } => {
            w.u8(TAG_FETCH_CHUNK);
            w.string(digest);
            w.u64(*offset);
            w.u32(*len);
        }
        Frame::PushManifest { json, digest } => {
            w.u8(TAG_PUSH_MANIFEST);
            w.string(json);
            w.string(digest);
        }
        Frame::PushChunk {
            digest,
            offset,
            total,
            bytes,
            chunk_digest,
        } => {
            w.u8(TAG_PUSH_CHUNK);
            w.string(digest);
            w.u64(*offset);
            w.u64(*total);
            w.bytes(bytes);
            w.string(chunk_digest);
        }
        Frame::ArtifactStat => w.u8(TAG_ARTIFACT_STAT),
        Frame::Welcome {
            version,
            server,
            resident,
        } => {
            w.u8(TAG_WELCOME);
            w.u16(*version);
            w.string(server);
            put_adapter_set(&mut w, resident);
        }
        Frame::Submitted {
            client_id,
            backend_id,
            events,
        } => {
            w.u8(TAG_SUBMITTED);
            w.u64(*client_id);
            w.u64(*backend_id);
            w.u32(events.len() as u32);
            for ev in events {
                put_event(&mut w, ev);
            }
        }
        Frame::Events { events, progressed } => {
            w.u8(TAG_EVENTS);
            w.u32(events.len() as u32);
            for (id, ev) in events {
                w.u64(*id);
                put_event(&mut w, ev);
            }
            w.bool(*progressed);
        }
        Frame::CancelResult { live } => {
            w.u8(TAG_CANCEL_RESULT);
            w.bool(*live);
        }
        Frame::StatsReply { stats } => {
            w.u8(TAG_STATS_REPLY);
            put_stats(&mut w, stats);
        }
        Frame::PrewarmResult { warmed } => {
            w.u8(TAG_PREWARM_RESULT);
            w.bool(*warmed);
        }
        Frame::ColdStartReply { stats } => {
            w.u8(TAG_COLD_START_REPLY);
            match stats {
                None => w.u8(0),
                Some(s) => {
                    w.u8(1);
                    w.usize(s.cold_admits);
                    w.usize(s.warm_admits);
                    w.usize(s.cpu_assisted);
                    w.usize(s.handoffs);
                    w.usize(s.deferred_collisions);
                    w.f64(s.assist_decode_s);
                }
            }
        }
        Frame::HeartbeatAck { nonce } => {
            w.u8(TAG_HEARTBEAT_ACK);
            w.u64(*nonce);
        }
        Frame::OkReply => w.u8(TAG_OK),
        Frame::ErrReply { message } => {
            w.u8(TAG_ERR);
            w.string(message);
        }
        Frame::ManifestReply {
            found,
            json,
            digest,
        } => {
            w.u8(TAG_MANIFEST_REPLY);
            w.bool(*found);
            w.string(json);
            w.string(digest);
        }
        Frame::ChunkReply {
            digest,
            offset,
            total,
            bytes,
            chunk_digest,
        } => {
            w.u8(TAG_CHUNK_REPLY);
            w.string(digest);
            w.u64(*offset);
            w.u64(*total);
            w.bytes(bytes);
            w.string(chunk_digest);
        }
        Frame::PushAck { complete, have } => {
            w.u8(TAG_PUSH_ACK);
            w.bool(*complete);
            w.u64(*have);
        }
        Frame::ArtifactStatReply {
            store_hits,
            synthetic_seeds,
            blobs,
        } => {
            w.u8(TAG_ARTIFACT_STAT_REPLY);
            w.u64(*store_hits);
            w.u64(*synthetic_seeds);
            w.u64(*blobs);
        }
    }
    w.out
}

/// Decode one frame. Never panics: every malformed input maps to a
/// [`WireError`].
pub fn decode(bytes: &[u8]) -> Result<Frame, WireError> {
    let mut r = Reader::new(bytes);
    let magic = r.u16()?;
    if magic != MAGIC {
        return Err(WireError::BadMagic { got: magic });
    }
    let version = r.u16()?;
    if version != VERSION {
        return Err(WireError::UnknownVersion { got: version });
    }
    let tag = r.u8()?;
    let frame = match tag {
        TAG_HELLO => Frame::Hello { client: r.string()? },
        TAG_SUBMIT => Frame::Submit {
            client_id: r.u64()?,
            req: get_request(&mut r)?,
        },
        TAG_POLL => Frame::Poll,
        TAG_CANCEL => Frame::Cancel { client_id: r.u64()? },
        TAG_STATS => Frame::Stats,
        TAG_INSTALL => Frame::Install {
            spec: get_spec(&mut r)?,
        },
        TAG_UNINSTALL => Frame::Uninstall { adapter: r.u64()? },
        TAG_PREWARM => Frame::Prewarm { adapter: r.u64()? },
        TAG_COLD_START => Frame::ColdStart,
        TAG_HEARTBEAT => Frame::Heartbeat { nonce: r.u64()? },
        TAG_SHUTDOWN => Frame::Shutdown,
        TAG_FETCH_MANIFEST => Frame::FetchManifest { adapter: r.u64()? },
        TAG_FETCH_CHUNK => {
            let digest = r.string()?;
            let offset = r.u64()?;
            let len = r.u32()?;
            // The *request* is also capped: a hostile fetch cannot ask
            // the server to materialize an oversized reply chunk.
            if len as usize > MAX_CHUNK_BYTES {
                return Err(WireError::ChunkTooLarge {
                    declared: len as usize,
                    max: MAX_CHUNK_BYTES,
                });
            }
            Frame::FetchChunk {
                digest,
                offset,
                len,
            }
        }
        TAG_PUSH_MANIFEST => Frame::PushManifest {
            json: r.string()?,
            digest: r.string()?,
        },
        TAG_PUSH_CHUNK => Frame::PushChunk {
            digest: r.string()?,
            offset: r.u64()?,
            total: r.u64()?,
            bytes: r.bytes()?,
            chunk_digest: r.string()?,
        },
        TAG_ARTIFACT_STAT => Frame::ArtifactStat,
        TAG_WELCOME => Frame::Welcome {
            version: r.u16()?,
            server: r.string()?,
            resident: get_adapter_set(&mut r)?,
        },
        TAG_SUBMITTED => {
            let client_id = r.u64()?;
            let backend_id = r.u64()?;
            let n = r.counted(1)?;
            let mut events = Vec::with_capacity(n);
            for _ in 0..n {
                events.push(get_event(&mut r)?);
            }
            Frame::Submitted {
                client_id,
                backend_id,
                events,
            }
        }
        TAG_EVENTS => {
            // Minimum 9 bytes per entry (u64 id + 1-byte event tag).
            let n = r.counted(9)?;
            let mut events = Vec::with_capacity(n);
            for _ in 0..n {
                let id = r.u64()?;
                events.push((id, get_event(&mut r)?));
            }
            Frame::Events {
                events,
                progressed: r.bool()?,
            }
        }
        TAG_CANCEL_RESULT => Frame::CancelResult { live: r.bool()? },
        TAG_STATS_REPLY => Frame::StatsReply {
            stats: get_stats(&mut r)?,
        },
        TAG_PREWARM_RESULT => Frame::PrewarmResult { warmed: r.bool()? },
        TAG_COLD_START_REPLY => Frame::ColdStartReply {
            stats: match r.u8()? {
                0 => None,
                1 => Some(ColdStartStats {
                    cold_admits: r.usize()?,
                    warm_admits: r.usize()?,
                    cpu_assisted: r.usize()?,
                    handoffs: r.usize()?,
                    deferred_collisions: r.usize()?,
                    assist_decode_s: r.f64()?,
                }),
                got => {
                    return Err(WireError::BadValue {
                        what: "option",
                        got: got as u64,
                    })
                }
            },
        },
        TAG_HEARTBEAT_ACK => Frame::HeartbeatAck { nonce: r.u64()? },
        TAG_OK => Frame::OkReply,
        TAG_ERR => Frame::ErrReply {
            message: r.string()?,
        },
        TAG_MANIFEST_REPLY => Frame::ManifestReply {
            found: r.bool()?,
            json: r.string()?,
            digest: r.string()?,
        },
        TAG_CHUNK_REPLY => Frame::ChunkReply {
            digest: r.string()?,
            offset: r.u64()?,
            total: r.u64()?,
            bytes: r.bytes()?,
            chunk_digest: r.string()?,
        },
        TAG_PUSH_ACK => Frame::PushAck {
            complete: r.bool()?,
            have: r.u64()?,
        },
        TAG_ARTIFACT_STAT_REPLY => Frame::ArtifactStatReply {
            store_hits: r.u64()?,
            synthetic_seeds: r.u64()?,
            blobs: r.u64()?,
        },
        tag => return Err(WireError::UnknownTag { tag, context: "frame" }),
    };
    let extra = r.remaining();
    if extra > 0 {
        return Err(WireError::Trailing { extra });
    }
    Ok(frame)
}

// ---------------------------------------------------------------------------
// Field codecs
// ---------------------------------------------------------------------------

fn put_request(w: &mut Writer, req: &ServeRequest) {
    w.u64(req.adapter);
    w.vec_i32(&req.prompt);
    w.usize(req.sampling.max_new_tokens);
    w.vec_i32(&req.sampling.stop_tokens);
    w.usize(req.sampling.top_k);
    w.u64(req.sampling.seed);
    w.u8(match req.priority {
        Priority::Batch => 0,
        Priority::Standard => 1,
        Priority::Interactive => 2,
    });
    match &req.slo {
        None => w.u8(0),
        Some(s) => {
            w.u8(1);
            w.f64(s.ttft_ms);
            w.f64(s.tpot_ms);
        }
    }
    match &req.resume {
        None => w.u8(0),
        Some(rs) => {
            w.u8(1);
            w.vec_i32(&rs.tokens);
        }
    }
}

fn get_request(r: &mut Reader) -> Result<ServeRequest, WireError> {
    let adapter = r.u64()?;
    let prompt = r.vec_i32()?;
    let sampling = SamplingParams {
        max_new_tokens: r.usize()?,
        stop_tokens: r.vec_i32()?,
        top_k: r.usize()?,
        seed: r.u64()?,
    };
    let priority = match r.u8()? {
        0 => Priority::Batch,
        1 => Priority::Standard,
        2 => Priority::Interactive,
        tag => return Err(WireError::UnknownTag { tag, context: "priority" }),
    };
    let slo = match r.u8()? {
        0 => None,
        1 => Some(SloSpec {
            ttft_ms: r.f64()?,
            tpot_ms: r.f64()?,
        }),
        got => {
            return Err(WireError::BadValue {
                what: "option",
                got: got as u64,
            })
        }
    };
    let resume = match r.u8()? {
        0 => None,
        1 => Some(ResumeState {
            tokens: r.vec_i32()?,
        }),
        got => {
            return Err(WireError::BadValue {
                what: "option",
                got: got as u64,
            })
        }
    };
    Ok(ServeRequest {
        adapter,
        prompt,
        sampling,
        priority,
        slo,
        resume,
    })
}

fn put_spec(w: &mut Writer, spec: &LoraSpec) {
    w.u64(spec.id);
    w.usize(spec.rank);
    w.string(&spec.base_model);
    w.u32(spec.targets.len() as u32);
    for t in &spec.targets {
        w.u8(match t {
            TargetMatrix::Q => 0,
            TargetMatrix::K => 1,
            TargetMatrix::V => 2,
            TargetMatrix::O => 3,
        });
    }
}

fn get_spec(r: &mut Reader) -> Result<LoraSpec, WireError> {
    let id = r.u64()?;
    let rank = r.usize()?;
    let base_model = r.string()?;
    let n = r.counted(1)?;
    let mut targets = Vec::with_capacity(n);
    for _ in 0..n {
        targets.push(match r.u8()? {
            0 => TargetMatrix::Q,
            1 => TargetMatrix::K,
            2 => TargetMatrix::V,
            3 => TargetMatrix::O,
            tag => return Err(WireError::UnknownTag { tag, context: "target" }),
        });
    }
    Ok(LoraSpec {
        id,
        rank,
        targets,
        base_model,
    })
}

fn put_event(w: &mut Writer, ev: &RequestEvent) {
    match ev {
        RequestEvent::Admitted => w.u8(0),
        RequestEvent::Routed { server } => {
            w.u8(1);
            w.usize(*server);
        }
        RequestEvent::FirstToken(t) => {
            w.u8(2);
            w.i32(*t);
        }
        RequestEvent::Token(t) => {
            w.u8(3);
            w.i32(*t);
        }
        RequestEvent::Finished(reason) => {
            w.u8(4);
            w.u8(match reason {
                FinishReason::Length => 0,
                FinishReason::Stop => 1,
            });
        }
        RequestEvent::Rerouted { from, to } => {
            w.u8(5);
            w.usize(*from);
            w.usize(*to);
        }
        RequestEvent::Cancelled => w.u8(6),
        RequestEvent::Rejected(reason) => {
            w.u8(7);
            put_reason(w, reason);
        }
    }
}

fn get_event(r: &mut Reader) -> Result<RequestEvent, WireError> {
    Ok(match r.u8()? {
        0 => RequestEvent::Admitted,
        1 => RequestEvent::Routed { server: r.usize()? },
        2 => RequestEvent::FirstToken(r.i32()?),
        3 => RequestEvent::Token(r.i32()?),
        4 => RequestEvent::Finished(match r.u8()? {
            0 => FinishReason::Length,
            1 => FinishReason::Stop,
            tag => return Err(WireError::UnknownTag { tag, context: "finish-reason" }),
        }),
        5 => RequestEvent::Rerouted {
            from: r.usize()?,
            to: r.usize()?,
        },
        6 => RequestEvent::Cancelled,
        7 => RequestEvent::Rejected(get_reason(r, 0)?),
        tag => return Err(WireError::UnknownTag { tag, context: "event" }),
    })
}

fn put_reason(w: &mut Writer, reason: &RejectReason) {
    match reason {
        RejectReason::PromptBounds { len, max_prompt } => {
            w.u8(0);
            w.usize(*len);
            w.usize(*max_prompt);
        }
        RejectReason::EmptyBudget => w.u8(1),
        RejectReason::KvCapacity { kv_capacity } => {
            w.u8(2);
            w.usize(*kv_capacity);
        }
        RejectReason::AdapterNotInstalled { adapter } => {
            w.u8(3);
            w.u64(*adapter);
        }
        RejectReason::AdapterNotRegistered { adapter } => {
            w.u8(4);
            w.u64(*adapter);
        }
        RejectReason::PoolTooSmall {
            adapter,
            pool_pages,
        } => {
            w.u8(5);
            w.u64(*adapter);
            w.usize(*pool_pages);
        }
        RejectReason::NoEligibleServer { last } => {
            w.u8(6);
            match last {
                None => w.u8(0),
                Some(inner) => {
                    w.u8(1);
                    put_reason(w, inner);
                }
            }
        }
        RejectReason::PolicyRepick { server } => {
            w.u8(7);
            w.usize(*server);
        }
        RejectReason::Overloaded { healthy, shed } => {
            w.u8(8);
            w.usize(*healthy);
            w.u8(match shed {
                Priority::Batch => 0,
                Priority::Standard => 1,
                Priority::Interactive => 2,
            });
        }
        RejectReason::BackendFailed { server } => {
            w.u8(9);
            w.usize(*server);
        }
        RejectReason::Other(s) => {
            w.u8(10);
            w.string(s);
        }
    }
}

fn get_reason(r: &mut Reader, depth: u8) -> Result<RejectReason, WireError> {
    if depth >= MAX_REASON_DEPTH {
        return Err(WireError::BadValue {
            what: "reason-depth",
            got: depth as u64,
        });
    }
    Ok(match r.u8()? {
        0 => RejectReason::PromptBounds {
            len: r.usize()?,
            max_prompt: r.usize()?,
        },
        1 => RejectReason::EmptyBudget,
        2 => RejectReason::KvCapacity {
            kv_capacity: r.usize()?,
        },
        3 => RejectReason::AdapterNotInstalled { adapter: r.u64()? },
        4 => RejectReason::AdapterNotRegistered { adapter: r.u64()? },
        5 => RejectReason::PoolTooSmall {
            adapter: r.u64()?,
            pool_pages: r.usize()?,
        },
        6 => RejectReason::NoEligibleServer {
            last: match r.u8()? {
                0 => None,
                1 => Some(Box::new(get_reason(r, depth + 1)?)),
                got => {
                    return Err(WireError::BadValue {
                        what: "option",
                        got: got as u64,
                    })
                }
            },
        },
        7 => RejectReason::PolicyRepick { server: r.usize()? },
        8 => RejectReason::Overloaded {
            healthy: r.usize()?,
            shed: match r.u8()? {
                0 => Priority::Batch,
                1 => Priority::Standard,
                2 => Priority::Interactive,
                tag => return Err(WireError::UnknownTag { tag, context: "priority" }),
            },
        },
        9 => RejectReason::BackendFailed { server: r.usize()? },
        10 => RejectReason::Other(r.string()?),
        tag => return Err(WireError::UnknownTag { tag, context: "reject-reason" }),
    })
}

fn put_adapter_set(w: &mut Writer, set: &AdapterSet) {
    match set {
        AdapterSet::Any => w.u8(0),
        AdapterSet::Only(ids) => {
            w.u8(1);
            w.u32(ids.len() as u32);
            for id in ids {
                w.u64(*id);
            }
        }
    }
}

fn get_adapter_set(r: &mut Reader) -> Result<AdapterSet, WireError> {
    match r.u8()? {
        0 => Ok(AdapterSet::Any),
        1 => {
            let n = r.counted(8)?;
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(r.u64()?);
            }
            // Re-sort/dedup on the way in: the invariant is the
            // receiver's to uphold, not the wire's to promise.
            Ok(AdapterSet::only(ids))
        }
        tag => Err(WireError::UnknownTag { tag, context: "adapter-set" }),
    }
}

fn put_stats(w: &mut Writer, s: &ServerStats) {
    w.u32(s.running_ranks.len() as u32);
    for rank in &s.running_ranks {
        w.usize(*rank);
    }
    w.u32(s.queued_ranks.len() as u32);
    for rank in &s.queued_ranks {
        w.usize(*rank);
    }
    put_adapter_set(w, &s.adapters);
    w.usize(s.max_prompt_tokens);
    w.usize(s.kv_free_tokens);
    match s.tpot_slo {
        None => w.u8(0),
        Some(v) => {
            w.u8(1);
            w.f64(v);
        }
    }
    w.usize(s.preemptions);
    w.usize(s.pool_pages);
    w.usize(s.kv_held_pages);
    w.usize(s.adapter_held_pages);
    w.usize(s.adapter_evictions);
    w.usize(s.event_overflows);
}

fn get_stats(r: &mut Reader) -> Result<ServerStats, WireError> {
    let n = r.counted(8)?;
    let mut running_ranks = Vec::with_capacity(n);
    for _ in 0..n {
        running_ranks.push(r.usize()?);
    }
    let n = r.counted(8)?;
    let mut queued_ranks = Vec::with_capacity(n);
    for _ in 0..n {
        queued_ranks.push(r.usize()?);
    }
    let adapters = get_adapter_set(r)?;
    let max_prompt_tokens = r.usize()?;
    let kv_free_tokens = r.usize()?;
    let tpot_slo = match r.u8()? {
        0 => None,
        1 => Some(r.f64()?),
        got => {
            return Err(WireError::BadValue {
                what: "option",
                got: got as u64,
            })
        }
    };
    Ok(ServerStats {
        running_ranks,
        queued_ranks,
        adapters,
        max_prompt_tokens,
        kv_free_tokens,
        tpot_slo,
        preemptions: r.usize()?,
        pool_pages: r.usize()?,
        kv_held_pages: r.usize()?,
        adapter_held_pages: r.usize()?,
        adapter_evictions: r.usize()?,
        event_overflows: r.usize()?,
    })
}

// ---------------------------------------------------------------------------
// Primitive writer/reader
// ---------------------------------------------------------------------------

struct Writer {
    out: Vec<u8>,
}

impl Writer {
    fn new() -> Writer {
        Writer { out: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.out.push(v);
    }
    fn bool(&mut self, v: bool) {
        self.out.push(v as u8);
    }
    fn u16(&mut self, v: u16) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    /// usize as u64 — `usize::MAX` (the "unmodeled" sentinel in
    /// [`ServerStats`]) maps to `u64::MAX` and back losslessly on
    /// 64-bit targets.
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.out.extend_from_slice(s.as_bytes());
    }
    /// Raw byte payload (artifact chunks). Encoding is total; the
    /// *decoder* enforces [`MAX_CHUNK_BYTES`], and honest senders chunk
    /// below it.
    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.out.extend_from_slice(b);
    }
    fn vec_i32(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.i32(*x);
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len().saturating_sub(self.pos)
    }

    /// Take the next `n` bytes, or a typed `Truncated` error.
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if n > self.remaining() {
            return Err(WireError::Truncated {
                need: n,
                have: self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a u32 element count and validate it against the bytes
    /// actually left (each element needs ≥ `min_elem_bytes`), so a
    /// corrupt count can never trigger a giant allocation.
    fn counted(&mut self, min_elem_bytes: usize) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        let need = n.checked_mul(min_elem_bytes);
        match need {
            Some(need) if need <= self.remaining() => Ok(n),
            _ => Err(WireError::Oversized {
                declared: n,
                have: self.remaining(),
            }),
        }
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            got => Err(WireError::BadValue {
                what: "bool",
                got: got as u64,
            }),
        }
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    fn i32(&mut self) -> Result<i32, WireError> {
        let s = self.take(4)?;
        Ok(i32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn usize(&mut self) -> Result<usize, WireError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| WireError::BadValue { what: "usize", got: v })
    }

    fn string(&mut self) -> Result<String, WireError> {
        let n = self.counted(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadString)
    }

    /// Raw byte payload with an absolute size cap: the declared length
    /// is checked against [`MAX_CHUNK_BYTES`] *before* the bytes-present
    /// check, so a hostile prefix is a typed [`WireError::ChunkTooLarge`]
    /// no matter how large the enclosing frame is.
    fn bytes(&mut self) -> Result<Vec<u8>, WireError> {
        let n = self.u32()? as usize;
        if n > MAX_CHUNK_BYTES {
            return Err(WireError::ChunkTooLarge {
                declared: n,
                max: MAX_CHUNK_BYTES,
            });
        }
        Ok(self.take(n)?.to_vec())
    }

    fn vec_i32(&mut self) -> Result<Vec<i32>, WireError> {
        let n = self.counted(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.i32()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let bytes = encode(&f);
        assert_eq!(decode(&bytes), Ok(f), "roundtrip through {bytes:?}");
    }

    #[test]
    fn simple_frames_roundtrip() {
        roundtrip(Frame::Hello {
            client: "router-0".into(),
        });
        roundtrip(Frame::Poll);
        roundtrip(Frame::Stats);
        roundtrip(Frame::ColdStart);
        roundtrip(Frame::Shutdown);
        roundtrip(Frame::OkReply);
        roundtrip(Frame::Cancel { client_id: 7 });
        roundtrip(Frame::Heartbeat { nonce: u64::MAX });
        roundtrip(Frame::HeartbeatAck { nonce: 0 });
        roundtrip(Frame::CancelResult { live: true });
        roundtrip(Frame::PrewarmResult { warmed: false });
        roundtrip(Frame::Submitted {
            client_id: 12,
            backend_id: 99,
            events: vec![
                RequestEvent::Admitted,
                RequestEvent::Rejected(RejectReason::EmptyBudget),
            ],
        });
        roundtrip(Frame::ColdStartReply { stats: None });
        roundtrip(Frame::ColdStartReply {
            stats: Some(ColdStartStats {
                cold_admits: 3,
                warm_admits: 9,
                cpu_assisted: 2,
                handoffs: 1,
                deferred_collisions: 0,
                assist_decode_s: 0.25,
            }),
        });
        roundtrip(Frame::Welcome {
            version: VERSION,
            server: "backend-1".into(),
            resident: AdapterSet::only(vec![4, 8]),
        });
        roundtrip(Frame::Welcome {
            version: VERSION,
            server: String::new(),
            resident: AdapterSet::Any,
        });
        roundtrip(Frame::Install {
            spec: LoraSpec::standard(5, 16, "tiny"),
        });
        roundtrip(Frame::Uninstall { adapter: 5 });
        roundtrip(Frame::Prewarm { adapter: 5 });
        roundtrip(Frame::ErrReply {
            message: "adapter 3 busy: 2 in-flight requests".into(),
        });
    }

    #[test]
    fn submit_roundtrips_every_field() {
        let req = ServeRequest::new(9, vec![1, -2, 3])
            .max_new_tokens(17)
            .stop_token(2)
            .top_k(4, 99)
            .priority(Priority::Interactive)
            .slo(150.0, 40.0);
        let mut req = req;
        req.resume = Some(ResumeState {
            tokens: vec![5, 6, 7],
        });
        roundtrip(Frame::Submit { client_id: 3, req });
    }

    #[test]
    fn stats_reply_roundtrips_sentinels() {
        roundtrip(Frame::StatsReply {
            stats: ServerStats::default(),
        });
        roundtrip(Frame::StatsReply {
            stats: ServerStats {
                running_ranks: vec![8, 64],
                queued_ranks: vec![16],
                adapters: AdapterSet::only(vec![3, 1, 1]),
                max_prompt_tokens: usize::MAX,
                kv_free_tokens: 4096,
                tpot_slo: Some(0.04),
                preemptions: 2,
                pool_pages: 40,
                kv_held_pages: 11,
                adapter_held_pages: 5,
                adapter_evictions: 1,
                event_overflows: 9,
            },
        });
    }

    #[test]
    fn nested_reject_reason_roundtrips() {
        let ev = RequestEvent::Rejected(RejectReason::NoEligibleServer {
            last: Some(Box::new(RejectReason::Overloaded {
                healthy: 1,
                shed: Priority::Batch,
            })),
        });
        roundtrip(Frame::Events {
            events: vec![(1, ev), (2, RequestEvent::Token(-5))],
            progressed: true,
        });
    }

    #[test]
    fn wrong_magic_version_and_tag_are_typed() {
        let mut bytes = encode(&Frame::Poll);
        bytes[0] ^= 0xFF;
        assert!(matches!(decode(&bytes), Err(WireError::BadMagic { .. })));

        let mut bytes = encode(&Frame::Poll);
        bytes[2] = 0xEE;
        assert!(matches!(
            decode(&bytes),
            Err(WireError::UnknownVersion { .. })
        ));

        let mut bytes = encode(&Frame::Poll);
        bytes[4] = 200;
        assert!(matches!(decode(&bytes), Err(WireError::UnknownTag { .. })));
    }

    #[test]
    fn truncation_and_trailing_are_typed() {
        let bytes = encode(&Frame::Hello {
            client: "abcdef".into(),
        });
        for cut in 0..bytes.len() {
            let r = decode(&bytes[..cut]);
            assert!(r.is_err(), "prefix of {cut} bytes decoded to {r:?}");
        }
        let mut padded = bytes.clone();
        padded.push(0);
        assert_eq!(decode(&padded), Err(WireError::Trailing { extra: 1 }));
    }

    #[test]
    fn oversized_declared_count_is_refused_before_allocation() {
        // A Hello whose string claims u32::MAX bytes in a tiny frame.
        let mut w = Writer::new();
        w.u16(MAGIC);
        w.u16(VERSION);
        w.u8(TAG_HELLO);
        w.u32(u32::MAX);
        w.u8(b'x');
        assert!(matches!(
            decode(&w.out),
            Err(WireError::Oversized { .. })
        ));
    }

    #[test]
    fn reason_recursion_is_depth_bounded() {
        // Hand-build an Events frame with a reject reason nested past
        // the bound: NoEligibleServer{Some(NoEligibleServer{Some(...)}}.
        let mut w = Writer::new();
        w.u16(MAGIC);
        w.u16(VERSION);
        w.u8(TAG_EVENTS);
        w.u32(1);
        w.u64(1);
        w.u8(7); // Rejected
        for _ in 0..40 {
            w.u8(6); // NoEligibleServer
            w.u8(1); // Some(..)
        }
        w.u8(1); // EmptyBudget terminates the chain
        w.bool(true);
        assert_eq!(
            decode(&w.out),
            Err(WireError::BadValue {
                what: "reason-depth",
                got: MAX_REASON_DEPTH as u64,
            })
        );
    }

    #[test]
    fn artifact_frames_roundtrip() {
        roundtrip(Frame::FetchManifest { adapter: 42 });
        roundtrip(Frame::FetchChunk {
            digest: "ab".repeat(32),
            offset: 1 << 40,
            len: MAX_CHUNK_BYTES as u32,
        });
        roundtrip(Frame::PushManifest {
            json: "{\n  \"adapter\": 1\n}".into(),
            digest: "0f".repeat(32),
        });
        roundtrip(Frame::PushChunk {
            digest: "12".repeat(32),
            offset: 0,
            total: 1024,
            bytes: (0..255u8).collect(),
            chunk_digest: "34".repeat(32),
        });
        roundtrip(Frame::ArtifactStat);
        roundtrip(Frame::ManifestReply {
            found: false,
            json: String::new(),
            digest: String::new(),
        });
        roundtrip(Frame::ManifestReply {
            found: true,
            json: "{}".into(),
            digest: "aa".repeat(32),
        });
        roundtrip(Frame::ChunkReply {
            digest: "bc".repeat(32),
            offset: 512,
            total: 4096,
            bytes: vec![],
            chunk_digest: "de".repeat(32),
        });
        roundtrip(Frame::PushAck {
            complete: true,
            have: u64::MAX,
        });
        roundtrip(Frame::ArtifactStatReply {
            store_hits: 3,
            synthetic_seeds: 0,
            blobs: 17,
        });
    }

    #[test]
    fn hostile_chunk_length_is_capped_before_allocation() {
        // A PushChunk whose byte payload declares > MAX_CHUNK_BYTES:
        // typed ChunkTooLarge, checked before the bytes-present check.
        let mut w = Writer::new();
        w.u16(MAGIC);
        w.u16(VERSION);
        w.u8(TAG_PUSH_CHUNK);
        w.string(&"ab".repeat(32));
        w.u64(0);
        w.u64(1 << 30);
        w.u32((MAX_CHUNK_BYTES + 1) as u32); // hostile length prefix
        w.u8(0xAA); // almost no actual payload
        assert_eq!(
            decode(&w.out),
            Err(WireError::ChunkTooLarge {
                declared: MAX_CHUNK_BYTES + 1,
                max: MAX_CHUNK_BYTES,
            })
        );

        // Same cap on the *request* side: an oversized FetchChunk len.
        let mut w = Writer::new();
        w.u16(MAGIC);
        w.u16(VERSION);
        w.u8(TAG_FETCH_CHUNK);
        w.string(&"cd".repeat(32));
        w.u64(0);
        w.u32(u32::MAX);
        assert_eq!(
            decode(&w.out),
            Err(WireError::ChunkTooLarge {
                declared: u32::MAX as usize,
                max: MAX_CHUNK_BYTES,
            })
        );
    }

    #[test]
    fn non_utf8_string_is_typed() {
        let mut w = Writer::new();
        w.u16(MAGIC);
        w.u16(VERSION);
        w.u8(TAG_ERR);
        w.u32(2);
        w.u8(0xFF);
        w.u8(0xFE);
        assert_eq!(decode(&w.out), Err(WireError::BadString));
    }
}
