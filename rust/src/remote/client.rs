//! [`RemoteFront`]: a [`ServingFront`] whose backend lives in another
//! OS process, reached over the [`crate::remote::wire`] protocol.
//!
//! The router composes these exactly like in-process backends — an
//! unchanged `ClusterFront` / `Coordinator` routes across processes.
//! Every trait call is one strict request-reply RPC; request events
//! arrive inside `poll`'s reply and are replayed into the same local
//! [`EventChannel`]s an in-process front would fill, so handles, token
//! logs, and the exactly-one-terminal contract are indistinguishable
//! from local serving.
//!
//! **Failure model — reconnect-with-state vs failover.** When the
//! connection breaks (send/receive error, reply timeout, undecodable
//! reply), the client tears the connection down and *orphans* its
//! in-flight channels without pushing a terminal: the next `poll`
//! surfaces an error, the router's health machine Downs this backend,
//! and PR 8 failover resumes each stream elsewhere from the
//! client-side token log — a fabricated terminal here would be relayed
//! as a real completion and defeat that. Later polls reconnect through
//! the stored socket path and re-handshake; the `Welcome` frame
//! reports the backend's resident adapter set, which the router's
//! Probation readmission inspects to decide between *rejoin-with-state*
//! (adapters survived: no re-install) and registry-driven re-install.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::wire::{self, Frame, WireError, MAX_CHUNK_BYTES};
use crate::artifacts::{hex_digest, ArtifactStore, Manifest};
use crate::ipc::socket::{SocketChannel, SocketError};
use crate::scheduler::{AdapterSet, ServerStats};
use crate::server::api::{
    EventChannel, InstallSourceStats, RejectReason, RequestEvent, RequestHandle, ServeRequest,
    ServingFront,
};
use crate::server::metrics::ColdStartStats;

/// Reply deadline for one RPC (also the reconnect handshake bound).
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(5);

/// Bytes per streamed artifact chunk. Small enough that a
/// [`RemoteFront::push_step`] call returns quickly (the overlap path
/// pumps one step between engine polls), comfortably under the
/// decoder's [`MAX_CHUNK_BYTES`] cap.
pub const DEFAULT_CHUNK_BYTES: usize = 32 << 10;

/// A remote call's failure, typed so callers can tell transport death
/// (reconnectable) from the peer refusing an operation (not).
#[derive(Debug)]
pub enum RemoteError {
    /// No connection and no socket path to re-establish one.
    Disconnected,
    /// Transport failure (send/receive error or reply timeout). The
    /// connection has been torn down; the next call reconnects.
    Socket(SocketError),
    /// The reply did not decode. Treated as transport death: a peer we
    /// cannot parse is a peer we cannot trust to stay frame-aligned.
    Wire(WireError),
    /// The peer replied with a frame the protocol does not allow here.
    Protocol {
        expected: &'static str,
        got: String,
    },
    /// The peer executed the request and reported an error (`ErrReply`).
    /// The connection stays up.
    Remote(String),
    /// An artifact transfer failed integrity or store validation on
    /// *this* side (chunk digest mismatch, bad manifest, local store
    /// rejection). The connection stays up; the transfer can retry.
    Store(String),
}

impl std::fmt::Display for RemoteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RemoteError::Disconnected => write!(f, "remote backend disconnected"),
            RemoteError::Socket(e) => write!(f, "remote transport failed: {e}"),
            RemoteError::Wire(e) => write!(f, "remote reply undecodable: {e}"),
            RemoteError::Protocol { expected, got } => {
                write!(f, "remote protocol violation: expected {expected}, got {got}")
            }
            RemoteError::Remote(msg) => write!(f, "remote backend error: {msg}"),
            RemoteError::Store(msg) => write!(f, "artifact transfer failed: {msg}"),
        }
    }
}

impl std::error::Error for RemoteError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RemoteError::Socket(e) => Some(e),
            RemoteError::Wire(e) => Some(e),
            _ => None,
        }
    }
}

/// Connection state behind the mutex ([`ServingFront::stats`] takes
/// `&self`, so every call path locks).
struct Conn {
    /// This client's name, sent in the handshake `Hello`.
    name: String,
    chan: Option<SocketChannel>,
    /// Socket path for reconnects; `None` for socketpair-mode fronts
    /// ([`RemoteFront::from_channel`]), which cannot reconnect.
    path: Option<PathBuf>,
    io_timeout: Duration,
    next_client_id: u64,
    /// Client request id → the local event channel its events replay
    /// into. BTreeMap for deterministic drain order.
    live: BTreeMap<u64, Arc<Mutex<EventChannel>>>,
    /// Resident adapter set reported by the last handshake.
    resident: AdapterSet,
    server_name: String,
    /// Successful re-handshakes after the initial connect.
    reconnects: usize,
    heartbeat_nonce: u64,
}

impl Conn {
    /// Tear the connection down and orphan in-flight channels — no
    /// fabricated terminals (see the module docs' failure model).
    fn drop_conn(&mut self) {
        self.chan = None;
        self.live.clear();
    }

    fn ensure_connected(&mut self) -> Result<(), RemoteError> {
        if self.chan.is_some() {
            return Ok(());
        }
        let Some(path) = self.path.clone() else {
            return Err(RemoteError::Disconnected);
        };
        let mut chan = SocketChannel::connect(&path)
            .map_err(|e| RemoteError::Socket(SocketError::Io(e)))?;
        let (server, resident) = handshake(&mut chan, &self.name, self.io_timeout)?;
        self.server_name = server;
        self.resident = resident;
        self.chan = Some(chan);
        self.reconnects += 1;
        Ok(())
    }

    /// One strict request-reply exchange. Any transport failure —
    /// including a reply timeout, since a late reply would desync the
    /// frame stream — tears the connection down.
    fn rpc(&mut self, frame: &Frame) -> Result<Frame, RemoteError> {
        let Some(chan) = self.chan.as_mut() else {
            return Err(RemoteError::Disconnected);
        };
        if let Err(e) = chan.send_bytes(&wire::encode(frame)) {
            self.drop_conn();
            return Err(RemoteError::Socket(SocketError::Io(e)));
        }
        let bytes = match chan.recv_bytes_deadline(self.io_timeout) {
            Ok(bytes) => bytes,
            Err(e) => {
                self.drop_conn();
                return Err(RemoteError::Socket(e));
            }
        };
        match wire::decode(&bytes) {
            Ok(Frame::ErrReply { message }) => Err(RemoteError::Remote(message)),
            Ok(reply) => Ok(reply),
            Err(e) => {
                self.drop_conn();
                Err(RemoteError::Wire(e))
            }
        }
    }

    /// A reply frame the protocol does not allow for this request:
    /// drop the connection (we are desynced) and build the typed error.
    fn unexpected(&mut self, expected: &'static str, got: Frame) -> RemoteError {
        self.drop_conn();
        RemoteError::Protocol {
            expected,
            got: format!("{got:?}"),
        }
    }
}

/// Handshake on a fresh channel: `Hello` → `Welcome`, returning the
/// backend's name and resident adapter set.
fn handshake(
    chan: &mut SocketChannel,
    client: &str,
    timeout: Duration,
) -> Result<(String, AdapterSet), RemoteError> {
    let hello = Frame::Hello {
        client: client.to_string(),
    };
    chan.send_bytes(&wire::encode(&hello))
        .map_err(|e| RemoteError::Socket(SocketError::Io(e)))?;
    let bytes = chan.recv_bytes_deadline(timeout).map_err(RemoteError::Socket)?;
    match wire::decode(&bytes).map_err(RemoteError::Wire)? {
        Frame::Welcome {
            version,
            server,
            resident,
        } => {
            if version != wire::VERSION {
                return Err(RemoteError::Protocol {
                    expected: "protocol version 1",
                    got: format!("version {version}"),
                });
            }
            Ok((server, resident))
        }
        Frame::ErrReply { message } => Err(RemoteError::Remote(message)),
        other => Err(RemoteError::Protocol {
            expected: "Welcome",
            got: format!("{other:?}"),
        }),
    }
}

/// A `ServingFront` backed by a backend host in another process.
pub struct RemoteFront {
    conn: Mutex<Conn>,
    /// Router-side artifact store. When attached and holding a manifest
    /// for an adapter being installed, [`ServingFront::install_adapter`]
    /// streams the adapter's blobs to the backend *before* the Install
    /// frame — the migration weight-transfer path.
    store: Option<Arc<Mutex<ArtifactStore>>>,
}

impl RemoteFront {
    /// Connect to a backend's Unix socket and handshake. Reconnects
    /// through the same path after transport failures.
    pub fn connect<P: Into<PathBuf>>(path: P, name: &str) -> anyhow::Result<RemoteFront> {
        RemoteFront::connect_with_timeout(path, name, DEFAULT_IO_TIMEOUT)
    }

    /// [`RemoteFront::connect`] with an explicit per-RPC reply deadline.
    pub fn connect_with_timeout<P: Into<PathBuf>>(
        path: P,
        name: &str,
        io_timeout: Duration,
    ) -> anyhow::Result<RemoteFront> {
        let mut conn = Conn {
            name: name.to_string(),
            chan: None,
            path: Some(path.into()),
            io_timeout,
            next_client_id: 0,
            live: BTreeMap::new(),
            resident: AdapterSet::only(vec![]),
            server_name: String::new(),
            reconnects: 0,
            heartbeat_nonce: 0,
        };
        conn.ensure_connected()
            .map_err(|e| anyhow::anyhow!("remote connect failed: {e}"))?;
        conn.reconnects = 0; // the initial connect is not a *re*connect
        Ok(RemoteFront {
            conn: Mutex::new(conn),
            store: None,
        })
    }

    /// Wrap one end of a socketpair whose peer is already being served
    /// (tests, in-process harnesses). No reconnect path.
    pub fn from_channel(
        mut chan: SocketChannel,
        name: &str,
        io_timeout: Duration,
    ) -> anyhow::Result<RemoteFront> {
        let (server_name, resident) = handshake(&mut chan, name, io_timeout)
            .map_err(|e| anyhow::anyhow!("remote handshake failed: {e}"))?;
        Ok(RemoteFront {
            conn: Mutex::new(Conn {
                name: name.to_string(),
                chan: Some(chan),
                path: None,
                io_timeout,
                next_client_id: 0,
                live: BTreeMap::new(),
                resident,
                server_name,
                reconnects: 0,
                heartbeat_nonce: 0,
            }),
            store: None,
        })
    }

    /// Attach the router-side artifact store this front sources
    /// streamed installs from (see the `store` field docs).
    pub fn attach_store(&mut self, store: Arc<Mutex<ArtifactStore>>) {
        self.store = Some(store);
    }

    /// The backend's self-reported name from the last handshake.
    pub fn server_name(&self) -> String {
        self.conn.lock().unwrap().server_name.clone()
    }

    /// Resident adapter set reported by the last handshake — the
    /// rejoin decision input (stale between handshakes by design; the
    /// live set comes from [`ServingFront::stats`]).
    pub fn resident(&self) -> AdapterSet {
        self.conn.lock().unwrap().resident.clone()
    }

    /// Successful re-handshakes since construction.
    pub fn reconnects(&self) -> usize {
        self.conn.lock().unwrap().reconnects
    }

    /// Whether a connection is currently up (false after a transport
    /// failure, until the next call reconnects).
    pub fn is_connected(&self) -> bool {
        self.conn.lock().unwrap().chan.is_some()
    }

    /// Liveness probe: round-trip a nonce without touching serving
    /// state.
    pub fn heartbeat(&self) -> Result<(), RemoteError> {
        let mut conn = self.conn.lock().unwrap();
        conn.heartbeat_nonce += 1;
        let nonce = conn.heartbeat_nonce;
        match conn.rpc(&Frame::Heartbeat { nonce })? {
            Frame::HeartbeatAck { nonce: got } if got == nonce => Ok(()),
            other => Err(conn.unexpected("HeartbeatAck", other)),
        }
    }

    /// Ask the backend host to exit its listener loop, then drop the
    /// connection.
    pub fn shutdown(&self) -> Result<(), RemoteError> {
        let mut conn = self.conn.lock().unwrap();
        let reply = conn.rpc(&Frame::Shutdown);
        conn.drop_conn();
        match reply? {
            Frame::OkReply => Ok(()),
            other => Err(RemoteError::Protocol {
                expected: "OkReply",
                got: format!("{other:?}"),
            }),
        }
    }

    // ---- artifact transfer ------------------------------------------------

    /// Fetch the backend store's manifest for `adapter`:
    /// `Some((canonical_json, digest))`, or `None` when the backend has
    /// no manifest for it. The text is verified against the digest
    /// before it is returned.
    pub fn fetch_manifest(&self, adapter: u64) -> Result<Option<(String, String)>, RemoteError> {
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()?;
        match conn.rpc(&Frame::FetchManifest { adapter })? {
            Frame::ManifestReply { found: false, .. } => Ok(None),
            Frame::ManifestReply {
                found: true,
                json,
                digest,
            } => {
                let got = hex_digest(json.as_bytes());
                if got != digest {
                    return Err(RemoteError::Store(format!(
                        "manifest for adapter {adapter} hashes to {got}, peer claims {digest}"
                    )));
                }
                Ok(Some((json, digest)))
            }
            other => Err(conn.unexpected("ManifestReply", other)),
        }
    }

    /// Stream blob `digest` from the backend into `store`, chunk by
    /// chunk, verifying the per-chunk digest on every reply; the store
    /// verifies the assembled blob against `digest` before committing.
    /// Returns the blob's total size (0 if it was already present).
    pub fn fetch_blob(
        &self,
        digest: &str,
        store: &mut ArtifactStore,
    ) -> Result<u64, RemoteError> {
        if store.has_blob(digest) {
            return Ok(0);
        }
        let mut offset = 0u64;
        loop {
            let mut conn = self.conn.lock().unwrap();
            conn.ensure_connected()?;
            let reply = conn.rpc(&Frame::FetchChunk {
                digest: digest.to_string(),
                offset,
                len: DEFAULT_CHUNK_BYTES.min(MAX_CHUNK_BYTES) as u32,
            })?;
            let (r_digest, r_offset, total, bytes, chunk_digest) = match reply {
                Frame::ChunkReply {
                    digest,
                    offset,
                    total,
                    bytes,
                    chunk_digest,
                } => (digest, offset, total, bytes, chunk_digest),
                other => return Err(conn.unexpected("ChunkReply", other)),
            };
            drop(conn);
            if r_digest != digest || r_offset != offset {
                return Err(RemoteError::Store(format!(
                    "chunk reply for blob {r_digest} @ {r_offset}, asked {digest} @ {offset}"
                )));
            }
            if hex_digest(&bytes) != chunk_digest {
                return Err(RemoteError::Store(format!(
                    "chunk at offset {offset} of blob {digest} failed its digest"
                )));
            }
            if bytes.is_empty() && offset < total {
                // Progress guard: an empty mid-blob chunk would loop
                // forever.
                return Err(RemoteError::Store(format!(
                    "empty chunk at offset {offset} of {total}-byte blob {digest}"
                )));
            }
            let complete = store
                .ingest_chunk(digest, offset, total, &bytes)
                .map_err(|e| RemoteError::Store(e.to_string()))?;
            offset += bytes.len() as u64;
            if complete {
                return Ok(total);
            }
        }
    }

    /// Pull `adapter` from the backend's store into `store`: manifest,
    /// then every blob the local store is missing (content addressing
    /// makes already-present blobs free), then the verified manifest
    /// install. Returns the manifest digest.
    pub fn pull_adapter(
        &self,
        adapter: u64,
        store: &Mutex<ArtifactStore>,
    ) -> Result<String, RemoteError> {
        let Some((json, digest)) = self.fetch_manifest(adapter)? else {
            return Err(RemoteError::Store(format!(
                "remote has no manifest for adapter {adapter}"
            )));
        };
        let manifest =
            Manifest::parse(&json).map_err(|e| RemoteError::Store(e.to_string()))?;
        for b in &manifest.blobs {
            let mut s = store.lock().unwrap();
            self.fetch_blob(&b.digest, &mut s)?;
        }
        store
            .lock()
            .unwrap()
            .publish_manifest(&json, &digest)
            .map_err(|e| RemoteError::Store(e.to_string()))?;
        Ok(digest)
    }

    /// Open a chunk-at-a-time push of `adapter` from the attached store
    /// to the backend. Blobs the backend already holds are detected via
    /// a zero-length fetch probe and skipped — cross-process dedup.
    /// Drive with [`RemoteFront::push_step`]; the overlap path
    /// interleaves steps with [`ServingFront::poll`] so the transfer
    /// rides inside the CPU-assist window.
    pub fn push_session(&self, adapter: u64) -> Result<PushSession, RemoteError> {
        let Some(store) = &self.store else {
            return Err(RemoteError::Store(
                "no artifact store attached to this RemoteFront".into(),
            ));
        };
        let (json, digest, blob_digests) = {
            let s = store.lock().unwrap();
            let (json, digest) = s
                .manifest_text(adapter)
                .map_err(|e| RemoteError::Store(e.to_string()))?;
            let blobs: Vec<String> = match s.manifest_of(adapter) {
                Some((_, m)) => m.blobs.iter().map(|b| b.digest.clone()).collect(),
                None => Vec::new(),
            };
            (json, digest, blobs)
        };
        let mut blobs = Vec::new();
        for bd in blob_digests {
            if self.remote_has_blob(&bd)? {
                continue;
            }
            let bytes = store
                .lock()
                .unwrap()
                .read_blob(&bd)
                .map_err(|e| RemoteError::Store(e.to_string()))?;
            blobs.push((bd, bytes));
        }
        let total_bytes = blobs.iter().map(|(_, b)| b.len() as u64).sum();
        Ok(PushSession {
            adapter,
            manifest_json: json,
            manifest_digest: digest,
            blobs,
            current: 0,
            offset: 0,
            manifest_sent: false,
            total_bytes,
            sent_bytes: 0,
        })
    }

    /// Does the backend's store already hold a blob? Probed with a
    /// zero-length chunk fetch: present blobs answer `ChunkReply`,
    /// missing ones a remote store error.
    fn remote_has_blob(&self, digest: &str) -> Result<bool, RemoteError> {
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()?;
        match conn.rpc(&Frame::FetchChunk {
            digest: digest.to_string(),
            offset: 0,
            len: 0,
        }) {
            Ok(Frame::ChunkReply { .. }) => Ok(true),
            Ok(other) => Err(conn.unexpected("ChunkReply", other)),
            Err(RemoteError::Remote(_)) => Ok(false),
            Err(e) => Err(e),
        }
    }

    /// Advance a push by one protocol exchange (one chunk, or the final
    /// manifest install). Returns `true` when the session is complete.
    pub fn push_step(&self, session: &mut PushSession) -> Result<bool, RemoteError> {
        if session.manifest_sent {
            return Ok(true);
        }
        if let Some((digest, bytes)) = session.blobs.get(session.current) {
            let total = bytes.len() as u64;
            let end = (session.offset + DEFAULT_CHUNK_BYTES).min(bytes.len());
            let chunk = &bytes[session.offset..end];
            let frame = Frame::PushChunk {
                digest: digest.clone(),
                offset: session.offset as u64,
                total,
                bytes: chunk.to_vec(),
                chunk_digest: hex_digest(chunk),
            };
            let mut conn = self.conn.lock().unwrap();
            conn.ensure_connected()?;
            let ack = match conn.rpc(&frame)? {
                Frame::PushAck { complete, have } => (complete, have),
                other => return Err(conn.unexpected("PushAck", other)),
            };
            drop(conn);
            session.offset = end;
            session.sent_bytes += chunk.len() as u64;
            let (complete, have) = ack;
            if complete {
                // Committed (possibly early, when the backend already
                // held the blob): move to the next one.
                session.current += 1;
                session.offset = 0;
            } else if have != end as u64 {
                return Err(RemoteError::Store(format!(
                    "push of blob {digest} desynced: backend staged {have}, sent {end}"
                )));
            }
            return Ok(false);
        }
        // All blobs delivered: install the manifest.
        let frame = Frame::PushManifest {
            json: session.manifest_json.clone(),
            digest: session.manifest_digest.clone(),
        };
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()?;
        match conn.rpc(&frame)? {
            Frame::OkReply => {
                session.manifest_sent = true;
                Ok(true)
            }
            other => Err(conn.unexpected("OkReply", other)),
        }
    }

    /// Push `adapter` to the backend in one blocking call (the
    /// serialized path; the overlap path drives [`RemoteFront::push_step`]
    /// itself). Returns the manifest digest.
    pub fn push_adapter(&self, adapter: u64) -> Result<String, RemoteError> {
        let mut session = self.push_session(adapter)?;
        while !self.push_step(&mut session)? {}
        Ok(session.manifest_digest)
    }

    /// The backend's install-provenance counters and blob census:
    /// `(store_hits, synthetic_seeds, blobs)`.
    pub fn artifact_stat(&self) -> Result<(u64, u64, u64), RemoteError> {
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()?;
        match conn.rpc(&Frame::ArtifactStat)? {
            Frame::ArtifactStatReply {
                store_hits,
                synthetic_seeds,
                blobs,
            } => Ok((store_hits, synthetic_seeds, blobs)),
            other => Err(conn.unexpected("ArtifactStatReply", other)),
        }
    }
}

/// An in-flight adapter push (see [`RemoteFront::push_session`]).
/// Holding it costs the undelivered blob bytes; chunking is bounded by
/// [`DEFAULT_CHUNK_BYTES`] ≤ [`MAX_CHUNK_BYTES`].
pub struct PushSession {
    adapter: u64,
    manifest_json: String,
    manifest_digest: String,
    /// Blobs the backend was missing at session open: (digest, bytes).
    blobs: Vec<(String, Vec<u8>)>,
    current: usize,
    offset: usize,
    manifest_sent: bool,
    total_bytes: u64,
    sent_bytes: u64,
}

impl PushSession {
    /// The adapter being pushed.
    pub fn adapter(&self) -> u64 {
        self.adapter
    }
    /// Blob bytes this session must deliver (deduped blobs excluded).
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }
    /// Blob bytes delivered so far.
    pub fn sent_bytes(&self) -> u64 {
        self.sent_bytes
    }
    /// Manifest digest being installed.
    pub fn manifest_digest(&self) -> &str {
        &self.manifest_digest
    }
    /// True once the manifest install acked.
    pub fn is_complete(&self) -> bool {
        self.manifest_sent
    }
}

impl ServingFront for RemoteFront {
    /// Ship the request over the wire. The reply's piggybacked events
    /// (Admitted, or a terminal Rejected) are replayed into the local
    /// channel before the handle is returned, so synchronous refusals
    /// stay synchronous — the router's re-route loop depends on that.
    /// Transport failures surface as `Rejected(Other)` on the handle:
    /// submit cannot return an error, and the router's submit path
    /// already treats a synchronous rejection as "pick another backend".
    fn submit(&mut self, req: ServeRequest) -> RequestHandle {
        let mut conn = self.conn.lock().unwrap();
        let client_id = conn.next_client_id;
        conn.next_client_id += 1;
        let (handle, channel) = RequestHandle::new(client_id);
        if let Err(e) = conn.ensure_connected() {
            push_reject(&channel, format!("remote backend unreachable: {e}"));
            return handle;
        }
        match conn.rpc(&Frame::Submit { client_id, req }) {
            Ok(Frame::Submitted {
                client_id: cid,
                events,
                ..
            }) if cid == client_id => {
                let mut terminal = false;
                {
                    let mut chan = channel.lock().unwrap();
                    for ev in events {
                        terminal |= ev.is_terminal();
                        chan.push(ev);
                    }
                }
                if !terminal {
                    conn.live.insert(client_id, channel);
                }
            }
            Ok(other) => {
                let e = conn.unexpected("Submitted", other);
                push_reject(&channel, format!("remote submit failed: {e}"));
            }
            Err(e) => push_reject(&channel, format!("remote submit failed: {e}")),
        }
        handle
    }

    /// One remote serving iteration: the backend polls its front and
    /// returns every event that produced; we replay them into the local
    /// channels. Errors propagate so the router's health machine sees
    /// them (poll is also where a torn-down connection reconnects).
    fn poll(&mut self) -> anyhow::Result<bool> {
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()
            .map_err(|e| anyhow::anyhow!("remote reconnect failed: {e}"))?;
        match conn.rpc(&Frame::Poll) {
            Ok(Frame::Events { events, progressed }) => {
                let mut retired = Vec::new();
                for (cid, ev) in events {
                    // Unknown ids (e.g. raced with a local drop) are
                    // skipped, not an error.
                    let Some(channel) = conn.live.get(&cid) else {
                        continue;
                    };
                    let terminal = ev.is_terminal();
                    channel.lock().unwrap().push(ev);
                    if terminal {
                        retired.push(cid);
                    }
                }
                for cid in retired {
                    conn.live.remove(&cid);
                }
                Ok(progressed)
            }
            Ok(other) => {
                let e = conn.unexpected("Events", other);
                anyhow::bail!("remote poll failed: {e}")
            }
            Err(e) => anyhow::bail!("remote poll failed: {e}"),
        }
    }

    fn cancel(&mut self, id: u64) -> bool {
        let mut conn = self.conn.lock().unwrap();
        if !conn.live.contains_key(&id) {
            return false;
        }
        match conn.rpc(&Frame::Cancel { client_id: id }) {
            Ok(Frame::CancelResult { live }) => live,
            Ok(other) => {
                let _ = conn.unexpected("CancelResult", other);
                false
            }
            Err(_) => false,
        }
    }

    /// The backend's stats plus this hop's own accounting
    /// (`event_overflows` from the local replay channels). While
    /// disconnected, reports an empty adapter set with zero capacity
    /// headroom so eligibility-based routing skips this backend until
    /// `poll` reconnects it.
    fn stats(&self) -> ServerStats {
        let mut conn = self.conn.lock().unwrap();
        let local_overflows: usize = conn
            .live
            .values()
            .map(|c| c.lock().unwrap().overflows())
            .sum();
        if conn.chan.is_some() {
            match conn.rpc(&Frame::Stats) {
                Ok(Frame::StatsReply { mut stats }) => {
                    stats.event_overflows += local_overflows;
                    return stats;
                }
                Ok(other) => {
                    let _ = conn.unexpected("StatsReply", other);
                }
                Err(_) => {}
            }
        }
        ServerStats {
            adapters: AdapterSet::only(vec![]),
            max_prompt_tokens: 0,
            kv_free_tokens: 0,
            event_overflows: local_overflows,
            ..Default::default()
        }
    }

    /// Install on the backend. When a local artifact store is attached
    /// and holds a manifest for the adapter, the weights are streamed
    /// to the backend first (deduped, digest-verified) so the Install
    /// frame lands as a store hit there, not a synthetic re-seed.
    fn install_adapter(&mut self, spec: &crate::model::LoraSpec) -> anyhow::Result<()> {
        let has_manifest = match &self.store {
            Some(store) => store.lock().unwrap().manifest_of(spec.id).is_some(),
            None => false,
        };
        if has_manifest {
            self.push_adapter(spec.id)
                .map_err(|e| anyhow::anyhow!("artifact push before install failed: {e}"))?;
        }
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()
            .map_err(|e| anyhow::anyhow!("remote install failed: {e}"))?;
        match conn.rpc(&Frame::Install { spec: spec.clone() }) {
            Ok(Frame::OkReply) => Ok(()),
            Ok(other) => {
                let e = conn.unexpected("OkReply", other);
                anyhow::bail!("remote install failed: {e}")
            }
            Err(e) => anyhow::bail!("remote install failed: {e}"),
        }
    }

    fn uninstall_adapter(&mut self, adapter: u64) -> anyhow::Result<()> {
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()
            .map_err(|e| anyhow::anyhow!("remote uninstall failed: {e}"))?;
        match conn.rpc(&Frame::Uninstall { adapter }) {
            Ok(Frame::OkReply) => Ok(()),
            Ok(other) => {
                let e = conn.unexpected("OkReply", other);
                anyhow::bail!("remote uninstall failed: {e}")
            }
            Err(e) => anyhow::bail!("remote uninstall failed: {e}"),
        }
    }

    fn prewarm_adapter(&mut self, adapter: u64) -> anyhow::Result<bool> {
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()
            .map_err(|e| anyhow::anyhow!("remote prewarm failed: {e}"))?;
        match conn.rpc(&Frame::Prewarm { adapter }) {
            Ok(Frame::PrewarmResult { warmed }) => Ok(warmed),
            Ok(other) => {
                let e = conn.unexpected("PrewarmResult", other);
                anyhow::bail!("remote prewarm failed: {e}")
            }
            Err(e) => anyhow::bail!("remote prewarm failed: {e}"),
        }
    }

    fn cold_start_stats(&self) -> Option<ColdStartStats> {
        let mut conn = self.conn.lock().unwrap();
        if conn.chan.is_none() {
            return None;
        }
        match conn.rpc(&Frame::ColdStart) {
            Ok(Frame::ColdStartReply { stats }) => stats,
            Ok(other) => {
                let _ = conn.unexpected("ColdStartReply", other);
                None
            }
            Err(_) => None,
        }
    }

    /// The backend's install-provenance counters (zeros while
    /// disconnected or against a pre-artifacts backend).
    fn install_source_stats(&self) -> InstallSourceStats {
        match self.artifact_stat() {
            Ok((store_hits, synthetic_seeds, _)) => InstallSourceStats {
                store_hits,
                synthetic_seeds,
            },
            Err(_) => InstallSourceStats::default(),
        }
    }
}

/// Terminal `Rejected(Other)` for transport-level submit failures.
fn push_reject(channel: &Arc<Mutex<EventChannel>>, why: String) {
    channel
        .lock()
        .unwrap()
        .push(RequestEvent::Rejected(RejectReason::Other(why)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;
    use crate::remote::server::serve_connection;
    use crate::server::api::LifecycleState;
    use crate::sim::{GpuModel, ServingMode, SimFront, SimInstance};

    /// Spawn a sim-backed host serving one socketpair connection and
    /// hand back the client's `RemoteFront`.
    fn remote_pair(adapters: &[(u64, usize)]) -> (RemoteFront, std::thread::JoinHandle<()>) {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::CaraServe, 32, 8, 64);
        let mut front = SimFront::new(inst, 512);
        for &(id, rank) in adapters {
            front.register_adapter(id, rank);
        }
        let (client_chan, mut server_chan) = SocketChannel::pair().expect("socketpair");
        let server = std::thread::spawn(move || {
            serve_connection(&mut front, &mut server_chan, "sim-host");
        });
        let front = RemoteFront::from_channel(client_chan, "test-router", DEFAULT_IO_TIMEOUT)
            .expect("handshake");
        (front, server)
    }

    #[test]
    fn end_to_end_stream_over_socketpair() {
        let (mut front, server) = remote_pair(&[(1, 8)]);
        assert_eq!(front.server_name(), "sim-host");
        assert!(front.resident().contains(1));

        let handle = front.submit(ServeRequest::new(1, vec![1, 2, 3]).max_new_tokens(5));
        assert_eq!(handle.state(), LifecycleState::Queued);
        front.run_until_idle().expect("run");
        assert_eq!(handle.state(), LifecycleState::Finished);
        // The simulator synthesizes tokens 0,1,2,… — the remote hop
        // must not perturb them.
        assert_eq!(handle.tokens(), vec![0, 1, 2, 3, 4]);
        let stats = front.stats();
        assert!(stats.can_serve(1));

        front.heartbeat().expect("heartbeat");
        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
    }

    #[test]
    fn synchronous_rejection_stays_synchronous() {
        let (mut front, server) = remote_pair(&[(1, 8)]);
        // Unregistered adapter: the rejection must be visible before
        // submit returns (the router's re-pick loop reads it).
        let handle = front.submit(ServeRequest::new(99, vec![1]));
        assert_eq!(handle.state(), LifecycleState::Rejected);
        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
    }

    #[test]
    fn cancel_round_trips() {
        let (mut front, server) = remote_pair(&[(1, 8)]);
        let handle = front.submit(ServeRequest::new(1, vec![1, 2]).max_new_tokens(30));
        assert!(front.cancel(handle.id()));
        front.run_until_idle().expect("run");
        assert_eq!(handle.state(), LifecycleState::Cancelled);
        assert!(!front.cancel(handle.id()), "retired ids report false");
        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
    }

    #[test]
    fn transport_death_orphans_streams_without_fake_terminals() {
        let (mut front, server) = remote_pair(&[(1, 8)]);
        let handle = front.submit(ServeRequest::new(1, vec![1, 2]).max_new_tokens(30));
        front.poll().expect("first poll");
        // Kill the host side; socketpair mode has no reconnect path.
        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
        assert!(front.poll().is_err(), "poll must surface the disconnect");
        assert!(
            !handle.is_terminal(),
            "no fabricated terminal: failover owns this stream now"
        );
        // Disconnected stats advertise nothing servable.
        let stats = front.stats();
        assert!(!stats.can_serve(1));
        // Submit after death rejects synchronously instead of hanging.
        let dead = front.submit(ServeRequest::new(1, vec![1]));
        assert_eq!(dead.state(), LifecycleState::Rejected);
    }

    #[test]
    fn install_uninstall_and_prewarm_round_trip() {
        let (mut front, server) = remote_pair(&[(1, 8)]);
        let spec = crate::model::LoraSpec::standard(7, 16, "llama2-7b");
        front.install_adapter(&spec).expect("install");
        assert!(front.stats().can_serve(7));
        assert!(front.prewarm_adapter(7).expect("prewarm"));
        front.uninstall_adapter(7).expect("uninstall");
        assert!(!front.stats().can_serve(7));
        // Remote-side refusals surface as errors, connection intact.
        assert!(front.uninstall_adapter(42).is_err());
        assert!(front.is_connected());
        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
    }

    /// Push and pull between two real stores over a socketpair:
    /// streamed blobs arrive bitwise-identical, shared blobs dedup to
    /// zero transfer bytes, and absent manifests are `None` not errors.
    #[test]
    fn artifact_push_pull_round_trip_with_dedup() {
        use crate::artifacts::{synthetic_stack, ArtifactStore};
        use crate::remote::server::serve_connection_with_store;

        let base = std::env::temp_dir()
            .join("caraserve-client-artifacts")
            .join(format!("pair-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let router_store = Arc::new(Mutex::new(
            ArtifactStore::open(&base.join("router")).expect("router store"),
        ));
        let backend_store = Arc::new(Mutex::new(
            ArtifactStore::open(&base.join("backend")).expect("backend store"),
        ));

        // Router store: adapter 7, plus adapter 9 published from the
        // *same* stack so the two manifests share all four blobs.
        let hidden = 16;
        let stack = synthetic_stack(7, hidden, 8);
        let mut rs = router_store.lock().unwrap();
        rs.publish(7, 8, "tiny", &stack).expect("publish 7");
        rs.publish(9, 8, "tiny", &stack).expect("publish 9");
        drop(rs);
        // Backend store: adapter 11, for the pull direction.
        let stack11 = synthetic_stack(11, hidden, 8);
        backend_store
            .lock()
            .unwrap()
            .publish(11, 8, "tiny", &stack11)
            .expect("publish 11");

        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::CaraServe, 32, 8, 64);
        let mut sim = SimFront::new(inst, 512);
        let (client_chan, mut server_chan) = SocketChannel::pair().expect("socketpair");
        let server_store = Arc::clone(&backend_store);
        let server = std::thread::spawn(move || {
            serve_connection_with_store(&mut sim, &mut server_chan, "sim-host", Some(&server_store));
        });
        let mut front = RemoteFront::from_channel(client_chan, "test-router", DEFAULT_IO_TIMEOUT)
            .expect("handshake");
        front.attach_store(Arc::clone(&router_store));

        // Push adapter 7: four blobs stream over, then the manifest.
        let digest7 = front.push_adapter(7).expect("push 7");
        {
            let bs = backend_store.lock().unwrap();
            let (d, _) = bs.manifest_of(7).expect("backend has manifest 7");
            assert_eq!(d, digest7);
        }
        let blobs_after_7 = backend_store.lock().unwrap().blob_count().expect("count");

        // Adapter 9 shares every blob with 7: the existence probe
        // dedups the payload down to just the manifest frame.
        let session = front.push_session(9).expect("session 9");
        assert_eq!(session.total_bytes(), 0);
        front.push_adapter(9).expect("push 9");
        assert_eq!(
            backend_store.lock().unwrap().blob_count().expect("count"),
            blobs_after_7
        );

        // Pull adapter 11 the other way: weights bitwise-identical.
        front.pull_adapter(11, &router_store).expect("pull 11");
        let rs = router_store.lock().unwrap();
        let (rank, pulled) = rs.load_stack(11, hidden).expect("load 11");
        assert_eq!(rank, 8);
        for (got, want) in pulled.iter().zip(stack11.iter()) {
            assert_eq!(got.a, want.a);
            assert_eq!(got.b, want.b);
        }
        drop(rs);

        // Absent manifest is a protocol outcome, not an error; the
        // stat frame reports the backend's blob census.
        assert!(front.fetch_manifest(999).expect("absent").is_none());
        let (_, _, blobs) = front.artifact_stat().expect("stat");
        assert_eq!(
            blobs,
            backend_store.lock().unwrap().blob_count().expect("count") as u64
        );

        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
        let _ = std::fs::remove_dir_all(&base);
    }
}
