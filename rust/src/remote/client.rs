//! [`RemoteFront`]: a [`ServingFront`] whose backend lives in another
//! OS process, reached over the [`crate::remote::wire`] protocol.
//!
//! The router composes these exactly like in-process backends — an
//! unchanged `ClusterFront` / `Coordinator` routes across processes.
//! Every trait call is one strict request-reply RPC; request events
//! arrive inside `poll`'s reply and are replayed into the same local
//! [`EventChannel`]s an in-process front would fill, so handles, token
//! logs, and the exactly-one-terminal contract are indistinguishable
//! from local serving.
//!
//! **Failure model — reconnect-with-state vs failover.** When the
//! connection breaks (send/receive error, reply timeout, undecodable
//! reply), the client tears the connection down and *orphans* its
//! in-flight channels without pushing a terminal: the next `poll`
//! surfaces an error, the router's health machine Downs this backend,
//! and PR 8 failover resumes each stream elsewhere from the
//! client-side token log — a fabricated terminal here would be relayed
//! as a real completion and defeat that. Later polls reconnect through
//! the stored socket path and re-handshake; the `Welcome` frame
//! reports the backend's resident adapter set, which the router's
//! Probation readmission inspects to decide between *rejoin-with-state*
//! (adapters survived: no re-install) and registry-driven re-install.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::wire::{self, Frame, WireError};
use crate::ipc::socket::{SocketChannel, SocketError};
use crate::scheduler::{AdapterSet, ServerStats};
use crate::server::api::{
    EventChannel, RejectReason, RequestEvent, RequestHandle, ServeRequest, ServingFront,
};
use crate::server::metrics::ColdStartStats;

/// Reply deadline for one RPC (also the reconnect handshake bound).
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(5);

/// A remote call's failure, typed so callers can tell transport death
/// (reconnectable) from the peer refusing an operation (not).
#[derive(Debug)]
pub enum RemoteError {
    /// No connection and no socket path to re-establish one.
    Disconnected,
    /// Transport failure (send/receive error or reply timeout). The
    /// connection has been torn down; the next call reconnects.
    Socket(SocketError),
    /// The reply did not decode. Treated as transport death: a peer we
    /// cannot parse is a peer we cannot trust to stay frame-aligned.
    Wire(WireError),
    /// The peer replied with a frame the protocol does not allow here.
    Protocol {
        expected: &'static str,
        got: String,
    },
    /// The peer executed the request and reported an error (`ErrReply`).
    /// The connection stays up.
    Remote(String),
}

impl std::fmt::Display for RemoteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RemoteError::Disconnected => write!(f, "remote backend disconnected"),
            RemoteError::Socket(e) => write!(f, "remote transport failed: {e}"),
            RemoteError::Wire(e) => write!(f, "remote reply undecodable: {e}"),
            RemoteError::Protocol { expected, got } => {
                write!(f, "remote protocol violation: expected {expected}, got {got}")
            }
            RemoteError::Remote(msg) => write!(f, "remote backend error: {msg}"),
        }
    }
}

impl std::error::Error for RemoteError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RemoteError::Socket(e) => Some(e),
            RemoteError::Wire(e) => Some(e),
            _ => None,
        }
    }
}

/// Connection state behind the mutex ([`ServingFront::stats`] takes
/// `&self`, so every call path locks).
struct Conn {
    /// This client's name, sent in the handshake `Hello`.
    name: String,
    chan: Option<SocketChannel>,
    /// Socket path for reconnects; `None` for socketpair-mode fronts
    /// ([`RemoteFront::from_channel`]), which cannot reconnect.
    path: Option<PathBuf>,
    io_timeout: Duration,
    next_client_id: u64,
    /// Client request id → the local event channel its events replay
    /// into. BTreeMap for deterministic drain order.
    live: BTreeMap<u64, Arc<Mutex<EventChannel>>>,
    /// Resident adapter set reported by the last handshake.
    resident: AdapterSet,
    server_name: String,
    /// Successful re-handshakes after the initial connect.
    reconnects: usize,
    heartbeat_nonce: u64,
}

impl Conn {
    /// Tear the connection down and orphan in-flight channels — no
    /// fabricated terminals (see the module docs' failure model).
    fn drop_conn(&mut self) {
        self.chan = None;
        self.live.clear();
    }

    fn ensure_connected(&mut self) -> Result<(), RemoteError> {
        if self.chan.is_some() {
            return Ok(());
        }
        let Some(path) = self.path.clone() else {
            return Err(RemoteError::Disconnected);
        };
        let mut chan = SocketChannel::connect(&path)
            .map_err(|e| RemoteError::Socket(SocketError::Io(e)))?;
        let (server, resident) = handshake(&mut chan, &self.name, self.io_timeout)?;
        self.server_name = server;
        self.resident = resident;
        self.chan = Some(chan);
        self.reconnects += 1;
        Ok(())
    }

    /// One strict request-reply exchange. Any transport failure —
    /// including a reply timeout, since a late reply would desync the
    /// frame stream — tears the connection down.
    fn rpc(&mut self, frame: &Frame) -> Result<Frame, RemoteError> {
        let Some(chan) = self.chan.as_mut() else {
            return Err(RemoteError::Disconnected);
        };
        if let Err(e) = chan.send_bytes(&wire::encode(frame)) {
            self.drop_conn();
            return Err(RemoteError::Socket(SocketError::Io(e)));
        }
        let bytes = match chan.recv_bytes_deadline(self.io_timeout) {
            Ok(bytes) => bytes,
            Err(e) => {
                self.drop_conn();
                return Err(RemoteError::Socket(e));
            }
        };
        match wire::decode(&bytes) {
            Ok(Frame::ErrReply { message }) => Err(RemoteError::Remote(message)),
            Ok(reply) => Ok(reply),
            Err(e) => {
                self.drop_conn();
                Err(RemoteError::Wire(e))
            }
        }
    }

    /// A reply frame the protocol does not allow for this request:
    /// drop the connection (we are desynced) and build the typed error.
    fn unexpected(&mut self, expected: &'static str, got: Frame) -> RemoteError {
        self.drop_conn();
        RemoteError::Protocol {
            expected,
            got: format!("{got:?}"),
        }
    }
}

/// Handshake on a fresh channel: `Hello` → `Welcome`, returning the
/// backend's name and resident adapter set.
fn handshake(
    chan: &mut SocketChannel,
    client: &str,
    timeout: Duration,
) -> Result<(String, AdapterSet), RemoteError> {
    let hello = Frame::Hello {
        client: client.to_string(),
    };
    chan.send_bytes(&wire::encode(&hello))
        .map_err(|e| RemoteError::Socket(SocketError::Io(e)))?;
    let bytes = chan.recv_bytes_deadline(timeout).map_err(RemoteError::Socket)?;
    match wire::decode(&bytes).map_err(RemoteError::Wire)? {
        Frame::Welcome {
            version,
            server,
            resident,
        } => {
            if version != wire::VERSION {
                return Err(RemoteError::Protocol {
                    expected: "protocol version 1",
                    got: format!("version {version}"),
                });
            }
            Ok((server, resident))
        }
        Frame::ErrReply { message } => Err(RemoteError::Remote(message)),
        other => Err(RemoteError::Protocol {
            expected: "Welcome",
            got: format!("{other:?}"),
        }),
    }
}

/// A `ServingFront` backed by a backend host in another process.
pub struct RemoteFront {
    conn: Mutex<Conn>,
}

impl RemoteFront {
    /// Connect to a backend's Unix socket and handshake. Reconnects
    /// through the same path after transport failures.
    pub fn connect<P: Into<PathBuf>>(path: P, name: &str) -> anyhow::Result<RemoteFront> {
        RemoteFront::connect_with_timeout(path, name, DEFAULT_IO_TIMEOUT)
    }

    /// [`RemoteFront::connect`] with an explicit per-RPC reply deadline.
    pub fn connect_with_timeout<P: Into<PathBuf>>(
        path: P,
        name: &str,
        io_timeout: Duration,
    ) -> anyhow::Result<RemoteFront> {
        let mut conn = Conn {
            name: name.to_string(),
            chan: None,
            path: Some(path.into()),
            io_timeout,
            next_client_id: 0,
            live: BTreeMap::new(),
            resident: AdapterSet::only(vec![]),
            server_name: String::new(),
            reconnects: 0,
            heartbeat_nonce: 0,
        };
        conn.ensure_connected()
            .map_err(|e| anyhow::anyhow!("remote connect failed: {e}"))?;
        conn.reconnects = 0; // the initial connect is not a *re*connect
        Ok(RemoteFront {
            conn: Mutex::new(conn),
        })
    }

    /// Wrap one end of a socketpair whose peer is already being served
    /// (tests, in-process harnesses). No reconnect path.
    pub fn from_channel(
        mut chan: SocketChannel,
        name: &str,
        io_timeout: Duration,
    ) -> anyhow::Result<RemoteFront> {
        let (server_name, resident) = handshake(&mut chan, name, io_timeout)
            .map_err(|e| anyhow::anyhow!("remote handshake failed: {e}"))?;
        Ok(RemoteFront {
            conn: Mutex::new(Conn {
                name: name.to_string(),
                chan: Some(chan),
                path: None,
                io_timeout,
                next_client_id: 0,
                live: BTreeMap::new(),
                resident,
                server_name,
                reconnects: 0,
                heartbeat_nonce: 0,
            }),
        })
    }

    /// The backend's self-reported name from the last handshake.
    pub fn server_name(&self) -> String {
        self.conn.lock().unwrap().server_name.clone()
    }

    /// Resident adapter set reported by the last handshake — the
    /// rejoin decision input (stale between handshakes by design; the
    /// live set comes from [`ServingFront::stats`]).
    pub fn resident(&self) -> AdapterSet {
        self.conn.lock().unwrap().resident.clone()
    }

    /// Successful re-handshakes since construction.
    pub fn reconnects(&self) -> usize {
        self.conn.lock().unwrap().reconnects
    }

    /// Whether a connection is currently up (false after a transport
    /// failure, until the next call reconnects).
    pub fn is_connected(&self) -> bool {
        self.conn.lock().unwrap().chan.is_some()
    }

    /// Liveness probe: round-trip a nonce without touching serving
    /// state.
    pub fn heartbeat(&self) -> Result<(), RemoteError> {
        let mut conn = self.conn.lock().unwrap();
        conn.heartbeat_nonce += 1;
        let nonce = conn.heartbeat_nonce;
        match conn.rpc(&Frame::Heartbeat { nonce })? {
            Frame::HeartbeatAck { nonce: got } if got == nonce => Ok(()),
            other => Err(conn.unexpected("HeartbeatAck", other)),
        }
    }

    /// Ask the backend host to exit its listener loop, then drop the
    /// connection.
    pub fn shutdown(&self) -> Result<(), RemoteError> {
        let mut conn = self.conn.lock().unwrap();
        let reply = conn.rpc(&Frame::Shutdown);
        conn.drop_conn();
        match reply? {
            Frame::OkReply => Ok(()),
            other => Err(RemoteError::Protocol {
                expected: "OkReply",
                got: format!("{other:?}"),
            }),
        }
    }
}

impl ServingFront for RemoteFront {
    /// Ship the request over the wire. The reply's piggybacked events
    /// (Admitted, or a terminal Rejected) are replayed into the local
    /// channel before the handle is returned, so synchronous refusals
    /// stay synchronous — the router's re-route loop depends on that.
    /// Transport failures surface as `Rejected(Other)` on the handle:
    /// submit cannot return an error, and the router's submit path
    /// already treats a synchronous rejection as "pick another backend".
    fn submit(&mut self, req: ServeRequest) -> RequestHandle {
        let mut conn = self.conn.lock().unwrap();
        let client_id = conn.next_client_id;
        conn.next_client_id += 1;
        let (handle, channel) = RequestHandle::new(client_id);
        if let Err(e) = conn.ensure_connected() {
            push_reject(&channel, format!("remote backend unreachable: {e}"));
            return handle;
        }
        match conn.rpc(&Frame::Submit { client_id, req }) {
            Ok(Frame::Submitted {
                client_id: cid,
                events,
                ..
            }) if cid == client_id => {
                let mut terminal = false;
                {
                    let mut chan = channel.lock().unwrap();
                    for ev in events {
                        terminal |= ev.is_terminal();
                        chan.push(ev);
                    }
                }
                if !terminal {
                    conn.live.insert(client_id, channel);
                }
            }
            Ok(other) => {
                let e = conn.unexpected("Submitted", other);
                push_reject(&channel, format!("remote submit failed: {e}"));
            }
            Err(e) => push_reject(&channel, format!("remote submit failed: {e}")),
        }
        handle
    }

    /// One remote serving iteration: the backend polls its front and
    /// returns every event that produced; we replay them into the local
    /// channels. Errors propagate so the router's health machine sees
    /// them (poll is also where a torn-down connection reconnects).
    fn poll(&mut self) -> anyhow::Result<bool> {
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()
            .map_err(|e| anyhow::anyhow!("remote reconnect failed: {e}"))?;
        match conn.rpc(&Frame::Poll) {
            Ok(Frame::Events { events, progressed }) => {
                let mut retired = Vec::new();
                for (cid, ev) in events {
                    // Unknown ids (e.g. raced with a local drop) are
                    // skipped, not an error.
                    let Some(channel) = conn.live.get(&cid) else {
                        continue;
                    };
                    let terminal = ev.is_terminal();
                    channel.lock().unwrap().push(ev);
                    if terminal {
                        retired.push(cid);
                    }
                }
                for cid in retired {
                    conn.live.remove(&cid);
                }
                Ok(progressed)
            }
            Ok(other) => {
                let e = conn.unexpected("Events", other);
                anyhow::bail!("remote poll failed: {e}")
            }
            Err(e) => anyhow::bail!("remote poll failed: {e}"),
        }
    }

    fn cancel(&mut self, id: u64) -> bool {
        let mut conn = self.conn.lock().unwrap();
        if !conn.live.contains_key(&id) {
            return false;
        }
        match conn.rpc(&Frame::Cancel { client_id: id }) {
            Ok(Frame::CancelResult { live }) => live,
            Ok(other) => {
                let _ = conn.unexpected("CancelResult", other);
                false
            }
            Err(_) => false,
        }
    }

    /// The backend's stats plus this hop's own accounting
    /// (`event_overflows` from the local replay channels). While
    /// disconnected, reports an empty adapter set with zero capacity
    /// headroom so eligibility-based routing skips this backend until
    /// `poll` reconnects it.
    fn stats(&self) -> ServerStats {
        let mut conn = self.conn.lock().unwrap();
        let local_overflows: usize = conn
            .live
            .values()
            .map(|c| c.lock().unwrap().overflows())
            .sum();
        if conn.chan.is_some() {
            match conn.rpc(&Frame::Stats) {
                Ok(Frame::StatsReply { mut stats }) => {
                    stats.event_overflows += local_overflows;
                    return stats;
                }
                Ok(other) => {
                    let _ = conn.unexpected("StatsReply", other);
                }
                Err(_) => {}
            }
        }
        ServerStats {
            adapters: AdapterSet::only(vec![]),
            max_prompt_tokens: 0,
            kv_free_tokens: 0,
            event_overflows: local_overflows,
            ..Default::default()
        }
    }

    fn install_adapter(&mut self, spec: &crate::model::LoraSpec) -> anyhow::Result<()> {
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()
            .map_err(|e| anyhow::anyhow!("remote install failed: {e}"))?;
        match conn.rpc(&Frame::Install { spec: spec.clone() }) {
            Ok(Frame::OkReply) => Ok(()),
            Ok(other) => {
                let e = conn.unexpected("OkReply", other);
                anyhow::bail!("remote install failed: {e}")
            }
            Err(e) => anyhow::bail!("remote install failed: {e}"),
        }
    }

    fn uninstall_adapter(&mut self, adapter: u64) -> anyhow::Result<()> {
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()
            .map_err(|e| anyhow::anyhow!("remote uninstall failed: {e}"))?;
        match conn.rpc(&Frame::Uninstall { adapter }) {
            Ok(Frame::OkReply) => Ok(()),
            Ok(other) => {
                let e = conn.unexpected("OkReply", other);
                anyhow::bail!("remote uninstall failed: {e}")
            }
            Err(e) => anyhow::bail!("remote uninstall failed: {e}"),
        }
    }

    fn prewarm_adapter(&mut self, adapter: u64) -> anyhow::Result<bool> {
        let mut conn = self.conn.lock().unwrap();
        conn.ensure_connected()
            .map_err(|e| anyhow::anyhow!("remote prewarm failed: {e}"))?;
        match conn.rpc(&Frame::Prewarm { adapter }) {
            Ok(Frame::PrewarmResult { warmed }) => Ok(warmed),
            Ok(other) => {
                let e = conn.unexpected("PrewarmResult", other);
                anyhow::bail!("remote prewarm failed: {e}")
            }
            Err(e) => anyhow::bail!("remote prewarm failed: {e}"),
        }
    }

    fn cold_start_stats(&self) -> Option<ColdStartStats> {
        let mut conn = self.conn.lock().unwrap();
        if conn.chan.is_none() {
            return None;
        }
        match conn.rpc(&Frame::ColdStart) {
            Ok(Frame::ColdStartReply { stats }) => stats,
            Ok(other) => {
                let _ = conn.unexpected("ColdStartReply", other);
                None
            }
            Err(_) => None,
        }
    }
}

/// Terminal `Rejected(Other)` for transport-level submit failures.
fn push_reject(channel: &Arc<Mutex<EventChannel>>, why: String) {
    channel
        .lock()
        .unwrap()
        .push(RequestEvent::Rejected(RejectReason::Other(why)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;
    use crate::remote::server::serve_connection;
    use crate::server::api::LifecycleState;
    use crate::sim::{GpuModel, ServingMode, SimFront, SimInstance};

    /// Spawn a sim-backed host serving one socketpair connection and
    /// hand back the client's `RemoteFront`.
    fn remote_pair(adapters: &[(u64, usize)]) -> (RemoteFront, std::thread::JoinHandle<()>) {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::CaraServe, 32, 8, 64);
        let mut front = SimFront::new(inst, 512);
        for &(id, rank) in adapters {
            front.register_adapter(id, rank);
        }
        let (client_chan, mut server_chan) = SocketChannel::pair().expect("socketpair");
        let server = std::thread::spawn(move || {
            serve_connection(&mut front, &mut server_chan, "sim-host");
        });
        let front = RemoteFront::from_channel(client_chan, "test-router", DEFAULT_IO_TIMEOUT)
            .expect("handshake");
        (front, server)
    }

    #[test]
    fn end_to_end_stream_over_socketpair() {
        let (mut front, server) = remote_pair(&[(1, 8)]);
        assert_eq!(front.server_name(), "sim-host");
        assert!(front.resident().contains(1));

        let handle = front.submit(ServeRequest::new(1, vec![1, 2, 3]).max_new_tokens(5));
        assert_eq!(handle.state(), LifecycleState::Queued);
        front.run_until_idle().expect("run");
        assert_eq!(handle.state(), LifecycleState::Finished);
        // The simulator synthesizes tokens 0,1,2,… — the remote hop
        // must not perturb them.
        assert_eq!(handle.tokens(), vec![0, 1, 2, 3, 4]);
        let stats = front.stats();
        assert!(stats.can_serve(1));

        front.heartbeat().expect("heartbeat");
        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
    }

    #[test]
    fn synchronous_rejection_stays_synchronous() {
        let (mut front, server) = remote_pair(&[(1, 8)]);
        // Unregistered adapter: the rejection must be visible before
        // submit returns (the router's re-pick loop reads it).
        let handle = front.submit(ServeRequest::new(99, vec![1]));
        assert_eq!(handle.state(), LifecycleState::Rejected);
        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
    }

    #[test]
    fn cancel_round_trips() {
        let (mut front, server) = remote_pair(&[(1, 8)]);
        let handle = front.submit(ServeRequest::new(1, vec![1, 2]).max_new_tokens(30));
        assert!(front.cancel(handle.id()));
        front.run_until_idle().expect("run");
        assert_eq!(handle.state(), LifecycleState::Cancelled);
        assert!(!front.cancel(handle.id()), "retired ids report false");
        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
    }

    #[test]
    fn transport_death_orphans_streams_without_fake_terminals() {
        let (mut front, server) = remote_pair(&[(1, 8)]);
        let handle = front.submit(ServeRequest::new(1, vec![1, 2]).max_new_tokens(30));
        front.poll().expect("first poll");
        // Kill the host side; socketpair mode has no reconnect path.
        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
        assert!(front.poll().is_err(), "poll must surface the disconnect");
        assert!(
            !handle.is_terminal(),
            "no fabricated terminal: failover owns this stream now"
        );
        // Disconnected stats advertise nothing servable.
        let stats = front.stats();
        assert!(!stats.can_serve(1));
        // Submit after death rejects synchronously instead of hanging.
        let dead = front.submit(ServeRequest::new(1, vec![1]));
        assert_eq!(dead.state(), LifecycleState::Rejected);
    }

    #[test]
    fn install_uninstall_and_prewarm_round_trip() {
        let (mut front, server) = remote_pair(&[(1, 8)]);
        let spec = crate::model::LoraSpec::standard(7, 16, "llama2-7b");
        front.install_adapter(&spec).expect("install");
        assert!(front.stats().can_serve(7));
        assert!(front.prewarm_adapter(7).expect("prewarm"));
        front.uninstall_adapter(7).expect("uninstall");
        assert!(!front.stats().can_serve(7));
        // Remote-side refusals surface as errors, connection intact.
        assert!(front.uninstall_adapter(42).is_err());
        assert!(front.is_connected());
        front.shutdown().expect("shutdown");
        server.join().expect("server thread");
    }
}
