//! The HTTP/1.1 JSON front door: the externally-reachable edge of the
//! distributed tier, hand-rolled over [`std::net::TcpListener`] (zero
//! new dependencies).
//!
//! Endpoints:
//!
//! - `POST /v1/requests` — body `{"adapter": 3, "prompt": [1,2,3],
//!   "max_new_tokens": 16, ...}`; replies `Transfer-Encoding: chunked`
//!   with one JSON line per request event (`{"id":N}` first, then
//!   `{"event":"token","token":t}` … ending in exactly one terminal
//!   event line), streaming tokens as the engine produces them.
//! - `DELETE /v1/requests/<id>` — cancel; replies `{"cancelled":bool}`.
//! - `GET /v1/stats` — the front's aggregated [`ServerStats`].
//!
//! Threading model: connection handler threads never touch the
//! [`ServingFront`] — they enqueue [`Cmd`]s over an mpsc channel and
//! the single serving thread ([`HttpGateway::run`]) drains them
//! between `poll`s, exactly like the CLI's existing drive loops. Token
//! streaming needs no cross-thread coordination because a
//! [`RequestHandle`]'s event channel is already `Arc<Mutex<…>>`-shared.
//!
//! [`soak`] is the load harness: N concurrent streaming clients, each
//! verifying its stream carries exactly one terminal event — the
//! acceptance oracle for "zero dropped terminals under load".

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::time::{Duration, Instant};

use crate::scheduler::ServerStats;
use crate::server::api::{Priority, RequestEvent, RequestHandle, ServeRequest, ServingFront};
use crate::util::json::{self, Json};

/// How long a handler waits for the serving thread to act on its
/// command before replying 503.
const CMD_TIMEOUT: Duration = Duration::from_secs(10);
/// Per-stream progress deadline: a stream with no event for this long
/// is closed (the client's exactly-one-terminal check then fails it
/// loudly rather than hanging forever).
const STREAM_STALL: Duration = Duration::from_secs(120);
/// Handler-side socket read timeout (slowloris bound).
const READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Largest accepted request body.
const MAX_BODY: usize = 1 << 20;

/// A connection handler's request to the serving thread.
enum Cmd {
    Submit {
        req: ServeRequest,
        reply: SyncSender<RequestHandle>,
    },
    Cancel {
        id: u64,
        reply: SyncSender<bool>,
    },
    Stats {
        reply: SyncSender<ServerStats>,
    },
}

/// The listening front door. Construct with [`HttpGateway::bind`],
/// then drive the serving side with [`HttpGateway::run`] (or
/// [`HttpGateway::pump`] from an existing drive loop).
pub struct HttpGateway {
    addr: SocketAddr,
    cmds: Receiver<Cmd>,
}

impl HttpGateway {
    /// Bind the listener and start the accept loop (a detached thread
    /// spawning one handler thread per connection; it lives until the
    /// process exits). `addr` is e.g. `"127.0.0.1:8090"` — pass port 0
    /// to let the kernel pick, then read [`HttpGateway::addr`].
    pub fn bind(addr: &str) -> anyhow::Result<HttpGateway> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let (tx, cmds) = mpsc::channel::<Cmd>();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                let tx = tx.clone();
                std::thread::spawn(move || {
                    let _ = handle_connection(stream, &tx);
                });
            }
        });
        Ok(HttpGateway { addr, cmds })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Drain pending handler commands into the front; returns how many
    /// were served. Call between `poll`s when embedding the gateway in
    /// an existing drive loop.
    pub fn pump(&self, front: &mut dyn ServingFront) -> usize {
        let mut served = 0;
        while let Ok(cmd) = self.cmds.try_recv() {
            served += 1;
            match cmd {
                Cmd::Submit { req, reply } => {
                    let _ = reply.send(front.submit(req));
                }
                Cmd::Cancel { id, reply } => {
                    let _ = reply.send(front.cancel(id));
                }
                Cmd::Stats { reply } => {
                    let _ = reply.send(front.stats());
                }
            }
        }
        served
    }

    /// Serve until `stop()` returns true: pump commands, poll the
    /// front, sleep briefly when idle. This is the backend router
    /// process's main loop under `caraserve serve --http`.
    pub fn run(&self, front: &mut dyn ServingFront, stop: &dyn Fn() -> bool) -> anyhow::Result<()> {
        while !stop() {
            let served = self.pump(front);
            let progressed = front.poll()?;
            if served == 0 && !progressed {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------------

/// Minimal parsed request: method, path, body.
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
}

/// Read one HTTP/1.1 request (head + `Content-Length` body).
fn read_request(stream: &mut TcpStream) -> anyhow::Result<HttpRequest> {
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let mut buf = Vec::new();
    let head_end = loop {
        let mut chunk = [0u8; 1024];
        let n = stream.read(&mut chunk)?;
        anyhow::ensure!(n > 0, "connection closed before request head");
        buf.extend_from_slice(&chunk[..n]);
        if let Some(pos) = find_crlfcrlf(&buf) {
            break pos;
        }
        anyhow::ensure!(buf.len() <= MAX_BODY, "request head too large");
    };
    let head = std::str::from_utf8(&buf[..head_end])?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    anyhow::ensure!(!method.is_empty() && !path.is_empty(), "bad request line");
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    anyhow::ensure!(content_length <= MAX_BODY, "request body too large");
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk)?;
        anyhow::ensure!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(HttpRequest { method, path, body })
}

fn find_crlfcrlf(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn write_response(stream: &mut TcpStream, status: &str, body: &str) -> std::io::Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n", data.len())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")
}

fn error_body(message: &str) -> String {
    json::obj(vec![("error", json::s(message))]).to_string_compact()
}

fn handle_connection(mut stream: TcpStream, tx: &Sender<Cmd>) -> anyhow::Result<()> {
    let req = match read_request(&mut stream) {
        Ok(req) => req,
        Err(e) => {
            let _ = write_response(&mut stream, "400 Bad Request", &error_body(&format!("{e}")));
            return Ok(());
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/requests") => handle_submit(&mut stream, tx, &req.body),
        ("GET", "/v1/stats") => handle_stats(&mut stream, tx),
        ("DELETE", path) if path.starts_with("/v1/requests/") => {
            handle_cancel(&mut stream, tx, path)
        }
        _ => {
            let _ = write_response(&mut stream, "404 Not Found", &error_body("no such endpoint"));
            Ok(())
        }
    }
}

/// Submit + stream: chunked JSON lines until the terminal event.
fn handle_submit(stream: &mut TcpStream, tx: &Sender<Cmd>, body: &[u8]) -> anyhow::Result<()> {
    let req = match parse_serve_request(body) {
        Ok(req) => req,
        Err(msg) => {
            let _ = write_response(stream, "400 Bad Request", &error_body(&msg));
            return Ok(());
        }
    };
    let (reply, rx) = mpsc::sync_channel(1);
    let handle = match tx
        .send(Cmd::Submit { req, reply })
        .ok()
        .and_then(|()| rx.recv_timeout(CMD_TIMEOUT).ok())
    {
        Some(handle) => handle,
        None => {
            let _ = write_response(
                stream,
                "503 Service Unavailable",
                &error_body("serving loop unavailable"),
            );
            return Ok(());
        }
    };
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    let first = json::obj(vec![("id", json::num(handle.id() as f64))]).to_string_compact();
    write_chunk(stream, format!("{first}\n").as_bytes())?;
    let mut last_progress = Instant::now();
    loop {
        let mut emitted = false;
        while let Some(event) = handle.poll_event() {
            emitted = true;
            let line = event_json(&event).to_string_compact();
            write_chunk(stream, format!("{line}\n").as_bytes())?;
            if event.is_terminal() {
                write!(stream, "0\r\n\r\n")?;
                return Ok(());
            }
        }
        if emitted {
            last_progress = Instant::now();
        } else {
            if last_progress.elapsed() > STREAM_STALL {
                // Close without the final 0-chunk: the client sees a
                // truncated stream and fails its terminal check loudly.
                return Ok(());
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}

fn handle_stats(stream: &mut TcpStream, tx: &Sender<Cmd>) -> anyhow::Result<()> {
    let (reply, rx) = mpsc::sync_channel(1);
    let stats = tx
        .send(Cmd::Stats { reply })
        .ok()
        .and_then(|()| rx.recv_timeout(CMD_TIMEOUT).ok());
    match stats {
        Some(stats) => {
            let body = stats_json(&stats).to_string_compact();
            let _ = write_response(stream, "200 OK", &body);
        }
        None => {
            let _ = write_response(
                stream,
                "503 Service Unavailable",
                &error_body("serving loop unavailable"),
            );
        }
    }
    Ok(())
}

fn handle_cancel(stream: &mut TcpStream, tx: &Sender<Cmd>, path: &str) -> anyhow::Result<()> {
    let id: u64 = match path.trim_start_matches("/v1/requests/").parse() {
        Ok(id) => id,
        Err(_) => {
            let _ = write_response(stream, "400 Bad Request", &error_body("bad request id"));
            return Ok(());
        }
    };
    let (reply, rx) = mpsc::sync_channel(1);
    let cancelled = tx
        .send(Cmd::Cancel { id, reply })
        .ok()
        .and_then(|()| rx.recv_timeout(CMD_TIMEOUT).ok());
    match cancelled {
        Some(live) => {
            let body = json::obj(vec![("cancelled", Json::Bool(live))]).to_string_compact();
            let _ = write_response(stream, "200 OK", &body);
        }
        None => {
            let _ = write_response(
                stream,
                "503 Service Unavailable",
                &error_body("serving loop unavailable"),
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// JSON mapping
// ---------------------------------------------------------------------------

/// Decode a `POST /v1/requests` body into a [`ServeRequest`].
fn parse_serve_request(body: &[u8]) -> Result<ServeRequest, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let j = Json::parse(text).map_err(|e| format!("bad json: {e}"))?;
    let adapter = j
        .get("adapter")
        .and_then(Json::as_f64)
        .ok_or("missing numeric field: adapter")? as u64;
    let prompt: Vec<i32> = j
        .get("prompt")
        .and_then(Json::as_arr)
        .ok_or("missing array field: prompt")?
        .iter()
        .map(|t| t.as_f64().map(|v| v as i32).ok_or("non-numeric prompt token"))
        .collect::<Result<_, _>>()?;
    if prompt.is_empty() {
        return Err("prompt must be non-empty".to_string());
    }
    let mut req = ServeRequest::new(adapter, prompt);
    if let Some(n) = j.get("max_new_tokens").and_then(Json::as_usize) {
        req = req.max_new_tokens(n);
    }
    if let Some(stops) = j.get("stop_tokens").and_then(Json::as_arr) {
        for t in stops {
            let t = t.as_f64().ok_or("non-numeric stop token")?;
            req = req.stop_token(t as i32);
        }
    }
    if let Some(k) = j.get("top_k").and_then(Json::as_usize) {
        req.sampling.top_k = k;
    }
    if let Some(seed) = j.get("seed").and_then(Json::as_f64) {
        req.sampling.seed = seed as u64;
    }
    if let Some(p) = j.get("priority").and_then(Json::as_str) {
        req = req.priority(match p {
            "batch" => Priority::Batch,
            "standard" => Priority::Standard,
            "interactive" => Priority::Interactive,
            other => return Err(format!("unknown priority {other:?}")),
        });
    }
    let ttft = j.get("ttft_ms").and_then(Json::as_f64);
    let tpot = j.get("tpot_ms").and_then(Json::as_f64);
    if let (Some(ttft_ms), Some(tpot_ms)) = (ttft, tpot) {
        req = req.slo(ttft_ms, tpot_ms);
    }
    Ok(req)
}

/// One request event as a JSON line object.
fn event_json(event: &RequestEvent) -> Json {
    match event {
        RequestEvent::Admitted => json::obj(vec![("event", json::s("admitted"))]),
        RequestEvent::Routed { server } => json::obj(vec![
            ("event", json::s("routed")),
            ("server", json::num(*server as f64)),
        ]),
        RequestEvent::FirstToken(t) => json::obj(vec![
            ("event", json::s("first_token")),
            ("token", json::num(*t as f64)),
        ]),
        RequestEvent::Token(t) => json::obj(vec![
            ("event", json::s("token")),
            ("token", json::num(*t as f64)),
        ]),
        RequestEvent::Finished(reason) => json::obj(vec![
            ("event", json::s("finished")),
            ("reason", json::s(&format!("{reason:?}").to_lowercase())),
        ]),
        RequestEvent::Rerouted { from, to } => json::obj(vec![
            ("event", json::s("rerouted")),
            ("from", json::num(*from as f64)),
            ("to", json::num(*to as f64)),
        ]),
        RequestEvent::Cancelled => json::obj(vec![("event", json::s("cancelled"))]),
        RequestEvent::Rejected(reason) => json::obj(vec![
            ("event", json::s("rejected")),
            ("reason", json::s(&format!("{reason:?}"))),
        ]),
    }
}

/// The stats surface exposed at `GET /v1/stats`.
fn stats_json(stats: &ServerStats) -> Json {
    fn bounded(v: usize) -> Json {
        if v == usize::MAX {
            Json::Null
        } else {
            json::num(v as f64)
        }
    }
    json::obj(vec![
        ("running", json::num(stats.running_ranks.len() as f64)),
        ("queued", json::num(stats.queued_ranks.len() as f64)),
        ("max_prompt_tokens", bounded(stats.max_prompt_tokens)),
        ("kv_free_tokens", bounded(stats.kv_free_tokens)),
        ("preemptions", json::num(stats.preemptions as f64)),
        ("event_overflows", json::num(stats.event_overflows as f64)),
        ("adapter_evictions", json::num(stats.adapter_evictions as f64)),
    ])
}

// ---------------------------------------------------------------------------
// Soak harness
// ---------------------------------------------------------------------------

/// Aggregate outcome of a [`soak`] run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SoakReport {
    pub clients: usize,
    pub requests: usize,
    /// Streams read to a clean end-of-response.
    pub completed: usize,
    /// Total terminal event lines observed.
    pub terminals: usize,
    /// Token events observed (first_token + token).
    pub tokens: usize,
    /// Streams that ended in `cancelled`.
    pub cancelled: usize,
    /// Transport / HTTP / JSON failures.
    pub errors: usize,
    /// Streams that ended with **no** terminal event — the acceptance
    /// criterion requires this to be zero.
    pub dropped_terminals: usize,
    /// Streams carrying more than one terminal event (must be zero).
    pub multi_terminals: usize,
}

impl SoakReport {
    /// The acceptance oracle: every stream completed with exactly one
    /// terminal.
    pub fn clean(&self) -> bool {
        self.errors == 0 && self.dropped_terminals == 0 && self.multi_terminals == 0
    }
}

/// Drive `clients` concurrent streaming clients against a gateway,
/// `requests_per_client` sequential requests each, verifying the
/// exactly-one-terminal contract per stream. Every `cancel_every`-th
/// request (0 = never) is cancelled mid-stream over a second
/// connection, exercising DELETE under load.
pub fn soak(
    addr: SocketAddr,
    clients: usize,
    requests_per_client: usize,
    adapters: u64,
    max_new_tokens: usize,
    cancel_every: usize,
) -> SoakReport {
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut part = SoakReport::default();
                for i in 0..requests_per_client {
                    let seq = c * requests_per_client + i;
                    let adapter = (seq as u64) % adapters.max(1);
                    let cancel = cancel_every > 0 && seq % cancel_every == cancel_every - 1;
                    part.requests += 1;
                    match stream_one(addr, adapter, max_new_tokens, cancel) {
                        Ok(s) => {
                            part.completed += 1;
                            part.terminals += s.terminals;
                            part.tokens += s.tokens;
                            part.cancelled += usize::from(s.saw_cancelled);
                            match s.terminals {
                                0 => part.dropped_terminals += 1,
                                1 => {}
                                _ => part.multi_terminals += 1,
                            }
                        }
                        Err(_) => part.errors += 1,
                    }
                }
                part
            })
        })
        .collect();
    let mut report = SoakReport {
        clients,
        ..SoakReport::default()
    };
    for worker in workers {
        let Ok(part) = worker.join() else {
            report.errors += 1;
            continue;
        };
        report.requests += part.requests;
        report.completed += part.completed;
        report.terminals += part.terminals;
        report.tokens += part.tokens;
        report.cancelled += part.cancelled;
        report.errors += part.errors;
        report.dropped_terminals += part.dropped_terminals;
        report.multi_terminals += part.multi_terminals;
    }
    report
}

/// One client stream's tally.
struct StreamOutcome {
    terminals: usize,
    tokens: usize,
    saw_cancelled: bool,
}

/// POST one request, stream the chunked reply to its end, optionally
/// firing a DELETE once the request id is known.
fn stream_one(
    addr: SocketAddr,
    adapter: u64,
    max_new_tokens: usize,
    cancel: bool,
) -> anyhow::Result<StreamOutcome> {
    let body = json::obj(vec![
        ("adapter", json::num(adapter as f64)),
        (
            "prompt",
            Json::Arr((0..8).map(|t| json::num(t as f64)).collect()),
        ),
        ("max_new_tokens", json::num(max_new_tokens as f64)),
    ])
    .to_string_compact();
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    write!(
        stream,
        "POST /v1/requests HTTP/1.1\r\nHost: caraserve\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    let mut reader = ChunkReader::new(stream)?;
    anyhow::ensure!(
        reader.status == 200,
        "unexpected status {}: {}",
        reader.status,
        String::from_utf8_lossy(&reader.buf)
    );
    let mut outcome = StreamOutcome {
        terminals: 0,
        tokens: 0,
        saw_cancelled: false,
    };
    let mut first = true;
    while let Some(chunk) = reader.next_chunk()? {
        for line in chunk.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
            let text = std::str::from_utf8(line)?;
            let j = Json::parse(text).map_err(|e| anyhow::anyhow!("bad event json: {e}"))?;
            if first {
                first = false;
                let id = j
                    .get("id")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("first line is not the id"))?
                    as u64;
                if cancel {
                    cancel_one(addr, id)?;
                }
                continue;
            }
            match j.get("event").and_then(Json::as_str) {
                Some("token") | Some("first_token") => outcome.tokens += 1,
                Some("finished") | Some("rejected") => outcome.terminals += 1,
                Some("cancelled") => {
                    outcome.terminals += 1;
                    outcome.saw_cancelled = true;
                }
                _ => {}
            }
        }
    }
    Ok(outcome)
}

/// Fire `DELETE /v1/requests/<id>` over a fresh connection.
fn cancel_one(addr: SocketAddr, id: u64) -> anyhow::Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    write!(
        stream,
        "DELETE /v1/requests/{id} HTTP/1.1\r\nHost: caraserve\r\nConnection: close\r\n\r\n"
    )?;
    let mut drain = Vec::new();
    let _ = stream.read_to_end(&mut drain);
    Ok(())
}

/// Incremental chunked-transfer decoder over a client socket: parses
/// the response head, then yields chunk payloads until the 0-chunk.
struct ChunkReader {
    stream: TcpStream,
    buf: Vec<u8>,
    status: u16,
}

impl ChunkReader {
    fn new(mut stream: TcpStream) -> anyhow::Result<ChunkReader> {
        let mut buf = Vec::new();
        let head_end = loop {
            if let Some(pos) = find_crlfcrlf(&buf) {
                break pos;
            }
            let mut chunk = [0u8; 1024];
            let n = stream.read(&mut chunk)?;
            anyhow::ensure!(n > 0, "connection closed before response head");
            buf.extend_from_slice(&chunk[..n]);
        };
        let head = std::str::from_utf8(&buf[..head_end])?.to_string();
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad status line: {head}"))?;
        buf.drain(..head_end + 4);
        Ok(ChunkReader { stream, buf, status })
    }

    /// The next chunk payload, or `None` after the terminating 0-chunk.
    fn next_chunk(&mut self) -> anyhow::Result<Option<Vec<u8>>> {
        loop {
            // "<hex>\r\n<payload>\r\n"
            if let Some(line_end) = self.buf.windows(2).position(|w| w == b"\r\n") {
                let size = usize::from_str_radix(
                    std::str::from_utf8(&self.buf[..line_end])?.trim(),
                    16,
                )?;
                let need = line_end + 2 + size + 2;
                if size == 0 {
                    return Ok(None);
                }
                if self.buf.len() >= need {
                    let payload = self.buf[line_end + 2..line_end + 2 + size].to_vec();
                    self.buf.drain(..need);
                    return Ok(Some(payload));
                }
            }
            let mut chunk = [0u8; 4096];
            let n = self.stream.read(&mut chunk)?;
            anyhow::ensure!(n > 0, "connection closed mid-stream (truncated chunk)");
            self.buf.extend_from_slice(&chunk[..n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;
    use crate::sim::{GpuModel, ServingMode, SimFront, SimInstance};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    fn gateway_over_sim(adapters: u64) -> (Arc<AtomicBool>, SocketAddr, std::thread::JoinHandle<()>) {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::CaraServe, 64, 8, 512);
        let mut front = SimFront::new(inst, 512);
        for id in 0..adapters {
            front.register_adapter(id, 16);
        }
        let gateway = HttpGateway::bind("127.0.0.1:0").expect("bind");
        let addr = gateway.addr();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let serving = std::thread::spawn(move || {
            gateway
                .run(&mut front, &|| stop2.load(Ordering::Relaxed))
                .expect("serving loop");
        });
        (stop, addr, serving)
    }

    #[test]
    fn soak_streams_have_exactly_one_terminal() {
        let (stop, addr, serving) = gateway_over_sim(4);
        let report = soak(addr, 8, 2, 4, 6, 0);
        stop.store(true, Ordering::Relaxed);
        serving.join().expect("serving thread");
        assert!(report.clean(), "soak not clean: {report:?}");
        assert_eq!(report.completed, 16);
        assert_eq!(report.terminals, 16);
        assert!(report.tokens > 0);
    }

    #[test]
    fn cancel_and_stats_endpoints_work_under_streaming() {
        let (stop, addr, serving) = gateway_over_sim(2);
        // Every 2nd request cancelled mid-stream over DELETE; long
        // budgets so cancels land before natural completion.
        let report = soak(addr, 4, 2, 2, 64, 2);
        // Stats endpoint round-trips while streams run.
        let mut stream = TcpStream::connect(addr).expect("connect");
        write!(
            stream,
            "GET /v1/stats HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"
        )
        .expect("write");
        let mut raw = Vec::new();
        stream.read_to_end(&mut raw).expect("read");
        let text = String::from_utf8_lossy(&raw);
        let body = text.split("\r\n\r\n").nth(1).expect("body");
        let j = Json::parse(body).expect("stats json");
        assert!(j.get("event_overflows").is_some());
        stop.store(true, Ordering::Relaxed);
        serving.join().expect("serving thread");
        assert!(report.clean(), "soak not clean: {report:?}");
        assert!(report.cancelled >= 1, "no cancel landed: {report:?}");
    }

    #[test]
    fn bad_requests_get_typed_errors() {
        let (stop, addr, serving) = gateway_over_sim(1);
        for (req, want) in [
            (
                "POST /v1/requests HTTP/1.1\r\nContent-Length: 9\r\n\r\n{\"bad\": 1",
                "400",
            ),
            ("GET /nope HTTP/1.1\r\n\r\n", "404"),
            ("DELETE /v1/requests/zzz HTTP/1.1\r\n\r\n", "400"),
        ] {
            let mut stream = TcpStream::connect(addr).expect("connect");
            stream.write_all(req.as_bytes()).expect("write");
            let mut raw = Vec::new();
            stream.read_to_end(&mut raw).expect("read");
            let text = String::from_utf8_lossy(&raw);
            assert!(
                text.starts_with(&format!("HTTP/1.1 {want}")),
                "want {want} for {req:?}, got: {text}"
            );
        }
        stop.store(true, Ordering::Relaxed);
        serving.join().expect("serving thread");
    }

    #[test]
    fn parse_serve_request_covers_the_surface() {
        let body = br#"{"adapter": 3, "prompt": [1, 2], "max_new_tokens": 4,
            "stop_tokens": [7], "top_k": 2, "seed": 9,
            "priority": "interactive", "ttft_ms": 500, "tpot_ms": 50}"#;
        let req = parse_serve_request(body).expect("parse");
        assert_eq!(req.adapter, 3);
        assert_eq!(req.prompt, vec![1, 2]);
        assert_eq!(req.sampling.max_new_tokens, 4);
        assert_eq!(req.sampling.stop_tokens, vec![7]);
        assert_eq!(req.sampling.top_k, 2);
        assert_eq!(req.sampling.seed, 9);
        assert_eq!(req.priority, Priority::Interactive);
        let slo = req.slo.expect("slo parsed");
        assert_eq!((slo.ttft_ms, slo.tpot_ms), (500.0, 50.0));
        assert!(parse_serve_request(b"{}").is_err());
        assert!(parse_serve_request(b"{\"adapter\":1,\"prompt\":[]}").is_err());
    }
}
