//! The backend host: serves the [`crate::remote::wire`] protocol over a
//! Unix socket in front of any [`ServingFront`] (native engine,
//! simulator, even a whole `ClusterFront`) — the process the
//! `caraserve backend` subcommand runs.
//!
//! The protocol is strict request-reply: every client frame gets
//! exactly one reply frame, and request events only flow inside the
//! reply to `Poll`. That keeps the host single-threaded (the front is
//! `&mut` throughout) and makes the router's deadline handling trivial.
//!
//! **Reconnect-with-state**: the listener loop serves one router
//! connection at a time; when a connection drops, in-flight requests
//! are cancelled and drained (their router failed them over already),
//! but the front itself — installed adapters, device residency, warm
//! caches — survives untouched. The next handshake's `Welcome` frame
//! reports the resident adapter set, which is what lets the router's
//! Probation→Healthy readmission skip re-installs when state survived
//! (and re-install from the registry when it did not).

use std::collections::BTreeMap;
use std::os::unix::net::UnixListener;
use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use super::wire::{self, Frame, WireError, VERSION};
use crate::artifacts::{hex_digest, ArtifactStore, StoreError};
use crate::ipc::SocketChannel;
use crate::server::api::{RequestHandle, ServingFront};

/// Why [`serve_connection`] returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnExit {
    /// The peer disconnected (or its stream broke). The front's state
    /// survives; the listener loop accepts the next connection.
    Disconnected,
    /// The peer sent `Shutdown`: exit the listener loop.
    ShutdownRequested,
}

/// Bind the backend's listening socket, replacing a stale socket file
/// from a previous (killed) incarnation — exactly the restart path the
/// rejoin machinery exercises.
pub fn bind<P: AsRef<Path>>(path: P) -> Result<UnixListener> {
    let path = path.as_ref();
    if path.exists() {
        std::fs::remove_file(path)?;
    }
    Ok(UnixListener::bind(path)?)
}

/// Accept-and-serve loop: one router connection at a time, each served
/// by [`serve_connection`], until a `Shutdown` frame (or a listener
/// error). Adapter state persists across connections.
pub fn serve_listener(
    front: &mut dyn ServingFront,
    listener: &UnixListener,
    name: &str,
) -> Result<()> {
    serve_listener_with_store(front, listener, name, None)
}

/// [`serve_listener`] with an attached [`ArtifactStore`]: the artifact
/// frames (`FetchManifest` / `FetchChunk` / `PushManifest` /
/// `PushChunk` / `ArtifactStat`) are served from/into it. Without a
/// store they answer with a typed `ErrReply`.
pub fn serve_listener_with_store(
    front: &mut dyn ServingFront,
    listener: &UnixListener,
    name: &str,
    store: Option<&Mutex<ArtifactStore>>,
) -> Result<()> {
    loop {
        let (stream, _) = listener.accept()?;
        let mut chan = SocketChannel::from_stream(stream);
        match serve_connection_with_store(front, &mut chan, name, store) {
            ConnExit::Disconnected => continue,
            ConnExit::ShutdownRequested => return Ok(()),
        }
    }
}

/// Serve one connection's frames until the peer disconnects or asks
/// for shutdown. Never returns an error: a broken stream is a normal
/// [`ConnExit::Disconnected`] (the front outlives its connections).
pub fn serve_connection(
    front: &mut dyn ServingFront,
    chan: &mut SocketChannel,
    name: &str,
) -> ConnExit {
    serve_connection_with_store(front, chan, name, None)
}

/// [`serve_connection`] with an attached [`ArtifactStore`] (see
/// [`serve_listener_with_store`]).
pub fn serve_connection_with_store(
    front: &mut dyn ServingFront,
    chan: &mut SocketChannel,
    name: &str,
    store: Option<&Mutex<ArtifactStore>>,
) -> ConnExit {
    // client request id → live handle; BTreeMap so Events frames list
    // requests in a deterministic order.
    let mut live: BTreeMap<u64, RequestHandle> = BTreeMap::new();
    loop {
        let bytes = match chan.recv_bytes() {
            Ok(b) => b,
            Err(_) => {
                quiesce(front, &mut live);
                return ConnExit::Disconnected;
            }
        };
        let (reply, exit) = match wire::decode(&bytes) {
            Ok(frame) => dispatch_with_store(front, &mut live, frame, name, store),
            // The socket layer delimits frames, so one undecodable
            // frame doesn't desynchronize the stream: report and keep
            // serving.
            Err(e) => (err_reply(&e), None),
        };
        if chan.send_bytes(&wire::encode(&reply)).is_err() {
            quiesce(front, &mut live);
            return ConnExit::Disconnected;
        }
        if let Some(exit) = exit {
            if exit == ConnExit::Disconnected {
                quiesce(front, &mut live);
            }
            return exit;
        }
    }
}

fn err_reply(e: &dyn std::fmt::Display) -> Frame {
    Frame::ErrReply {
        message: format!("{e}"),
    }
}

/// Run an artifact-frame handler against the attached store, mapping
/// "no store" and a poisoned lock to typed `ErrReply` frames.
fn with_store(
    store: Option<&Mutex<ArtifactStore>>,
    f: impl FnOnce(&mut ArtifactStore) -> Frame,
) -> Frame {
    match store {
        None => err_reply(&format_args!("no artifact store attached to this backend")),
        Some(m) => match m.lock() {
            Ok(mut s) => f(&mut s),
            Err(_) => err_reply(&format_args!("artifact store lock poisoned")),
        },
    }
}

/// Handle one decoded frame; returns the reply and, when the
/// connection should end after it, the exit kind.
fn dispatch_with_store(
    front: &mut dyn ServingFront,
    live: &mut BTreeMap<u64, RequestHandle>,
    frame: Frame,
    name: &str,
    store: Option<&Mutex<ArtifactStore>>,
) -> (Frame, Option<ConnExit>) {
    let reply = match frame {
        Frame::Hello { client: _ } => Frame::Welcome {
            version: VERSION,
            server: name.to_string(),
            resident: front.stats().adapters,
        },
        Frame::Submit { client_id, req } => {
            if live.contains_key(&client_id) {
                err_reply(&format_args!("client request id {client_id} already live"))
            } else {
                let handle = front.submit(req);
                // Synchronous lifecycle output (Admitted, or a terminal
                // Rejected) rides back on the reply so the router's
                // re-route loop sees refusals immediately.
                let events = handle.drain_events();
                let backend_id = handle.id();
                if !handle.is_terminal() {
                    live.insert(client_id, handle);
                }
                Frame::Submitted {
                    client_id,
                    backend_id,
                    events,
                }
            }
        }
        Frame::Poll => match front.poll() {
            Ok(progressed) => {
                let mut events = Vec::new();
                let mut done = Vec::new();
                for (&cid, handle) in live.iter() {
                    for ev in handle.drain_events() {
                        events.push((cid, ev));
                    }
                    if handle.is_terminal() {
                        done.push(cid);
                    }
                }
                for cid in done {
                    live.remove(&cid);
                }
                Frame::Events { events, progressed }
            }
            Err(e) => err_reply(&format_args!("{e:#}")),
        },
        Frame::Cancel { client_id } => Frame::CancelResult {
            live: match live.get(&client_id) {
                Some(handle) => front.cancel(handle.id()),
                None => false,
            },
        },
        Frame::Stats => Frame::StatsReply {
            stats: front.stats(),
        },
        Frame::Install { spec } => match front.install_adapter(&spec) {
            Ok(()) => Frame::OkReply,
            Err(e) => err_reply(&format_args!("{e:#}")),
        },
        Frame::Uninstall { adapter } => match front.uninstall_adapter(adapter) {
            Ok(()) => Frame::OkReply,
            Err(e) => err_reply(&format_args!("{e:#}")),
        },
        Frame::Prewarm { adapter } => match front.prewarm_adapter(adapter) {
            Ok(warmed) => Frame::PrewarmResult { warmed },
            Err(e) => err_reply(&format_args!("{e:#}")),
        },
        Frame::ColdStart => Frame::ColdStartReply {
            stats: front.cold_start_stats(),
        },
        Frame::Heartbeat { nonce } => Frame::HeartbeatAck { nonce },
        Frame::Shutdown => return (Frame::OkReply, Some(ConnExit::ShutdownRequested)),
        Frame::FetchManifest { adapter } => with_store(store, |s| {
            match s.manifest_text(adapter) {
                Ok((json, digest)) => Frame::ManifestReply {
                    found: true,
                    json,
                    digest,
                },
                // Absence is a protocol outcome the router probes for,
                // not an error.
                Err(StoreError::NotFound { .. }) => Frame::ManifestReply {
                    found: false,
                    json: String::new(),
                    digest: String::new(),
                },
                Err(e) => err_reply(&e),
            }
        }),
        Frame::FetchChunk {
            digest,
            offset,
            len,
        } => with_store(store, |s| match s.chunk_of(&digest, offset, len as usize) {
            Ok((bytes, total)) => {
                let chunk_digest = hex_digest(&bytes);
                Frame::ChunkReply {
                    digest: digest.clone(),
                    offset,
                    total,
                    bytes,
                    chunk_digest,
                }
            }
            Err(e) => err_reply(&e),
        }),
        Frame::PushManifest { json, digest } => {
            with_store(store, |s| match s.publish_manifest(&json, &digest) {
                Ok(_adapter) => Frame::OkReply,
                Err(e) => err_reply(&e),
            })
        }
        Frame::PushChunk {
            digest,
            offset,
            total,
            bytes,
            chunk_digest,
        } => with_store(store, |s| {
            // Per-chunk integrity before any staging: a flipped bit is
            // caught at the chunk that carried it, not at blob commit.
            let got = hex_digest(&bytes);
            if got != chunk_digest {
                return err_reply(&format_args!(
                    "chunk at offset {offset} of blob {digest} is corrupt (hashes to {got})"
                ));
            }
            match s.ingest_chunk(&digest, offset, total, &bytes) {
                Ok(complete) => Frame::PushAck {
                    complete,
                    have: if complete { total } else { s.staged_len(&digest) },
                },
                Err(e) => err_reply(&e),
            }
        }),
        Frame::ArtifactStat => {
            let sources = front.install_source_stats();
            let blobs = match store {
                Some(m) => match m.lock() {
                    Ok(s) => s.blob_count().unwrap_or(0) as u64,
                    Err(_) => 0,
                },
                None => 0,
            };
            Frame::ArtifactStatReply {
                store_hits: sources.store_hits,
                synthetic_seeds: sources.synthetic_seeds,
                blobs,
            }
        }
        // Reply-direction frames arriving as requests are a peer bug.
        other => err_reply(&format_args!("unexpected frame {other:?}")),
    };
    (reply, None)
}

/// Cancel and drain every request the departed connection left in
/// flight, so the next connection (and the front's own queues) start
/// clean. Adapter state is deliberately untouched — that is the
/// "with-state" half of reconnect-with-state.
fn quiesce(front: &mut dyn ServingFront, live: &mut BTreeMap<u64, RequestHandle>) {
    for handle in live.values() {
        front.cancel(handle.id());
    }
    // Drive the cancellations to their terminal events; a front erroring
    // here has nothing further to drain.
    let _ = front.run_until_idle();
    for handle in live.values() {
        let _ = handle.drain_events();
    }
    live.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;
    use crate::server::api::{LifecycleState, RequestEvent, ServeRequest};
    use crate::sim::{GpuModel, ServingMode, SimFront, SimInstance};

    fn sim_front() -> SimFront {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::CaraServe, 32, 8, 64);
        let mut front = SimFront::new(inst, 512);
        front.register_adapter(1, 8);
        front
    }

    fn rpc(front: &mut dyn ServingFront, live: &mut BTreeMap<u64, RequestHandle>, f: Frame) -> Frame {
        let (reply, exit) = dispatch_with_store(front, live, f, "test-backend", None);
        assert!(exit.is_none());
        reply
    }

    #[test]
    fn submit_poll_drain_lifecycle() {
        let mut front = sim_front();
        let mut live = BTreeMap::new();
        let req = ServeRequest::new(1, vec![1, 2, 3]).max_new_tokens(4);
        let reply = rpc(
            &mut front,
            &mut live,
            Frame::Submit { client_id: 10, req },
        );
        let Frame::Submitted {
            client_id, events, ..
        } = reply
        else {
            panic!("expected Submitted, got {reply:?}");
        };
        assert_eq!(client_id, 10);
        assert_eq!(events, vec![RequestEvent::Admitted]);
        assert!(live.contains_key(&10));

        let mut seen = Vec::new();
        for _ in 0..64 {
            let reply = rpc(&mut front, &mut live, Frame::Poll);
            let Frame::Events { events, .. } = reply else {
                panic!("expected Events, got {reply:?}");
            };
            seen.extend(events);
            if live.is_empty() {
                break;
            }
        }
        assert!(live.is_empty(), "request never terminated");
        assert!(seen.iter().all(|(cid, _)| *cid == 10));
        assert_eq!(
            seen.iter().filter(|(_, ev)| ev.is_terminal()).count(),
            1,
            "exactly one terminal: {seen:?}"
        );
    }

    #[test]
    fn synchronous_rejection_rides_the_submit_reply() {
        let mut front = sim_front();
        let mut live = BTreeMap::new();
        // Adapter 9 is not registered: SimFront rejects at submit.
        let req = ServeRequest::new(9, vec![1]);
        let reply = rpc(&mut front, &mut live, Frame::Submit { client_id: 1, req });
        let Frame::Submitted { events, .. } = reply else {
            panic!("expected Submitted, got {reply:?}");
        };
        assert!(
            events.iter().any(|ev| ev.is_terminal()),
            "rejection must be synchronous: {events:?}"
        );
        assert!(live.is_empty(), "terminal request must not stay live");
    }

    #[test]
    fn quiesce_cancels_in_flight_and_preserves_adapters() {
        let mut front = sim_front();
        let mut live = BTreeMap::new();
        let req = ServeRequest::new(1, vec![1, 2]).max_new_tokens(8);
        rpc(&mut front, &mut live, Frame::Submit { client_id: 5, req });
        // Keep a view of the backend handle to check the terminal.
        let handle = live.get(&5).unwrap().clone();
        quiesce(&mut front, &mut live);
        assert!(live.is_empty());
        assert_eq!(handle.state(), LifecycleState::Cancelled);
        // The "state" in reconnect-with-state: adapters survive.
        assert!(front.stats().can_serve(1));
    }

    #[test]
    fn hello_reports_resident_adapters() {
        let mut front = sim_front();
        let mut live = BTreeMap::new();
        let reply = rpc(
            &mut front,
            &mut live,
            Frame::Hello {
                client: "router".into(),
            },
        );
        let Frame::Welcome {
            version, resident, ..
        } = reply
        else {
            panic!("expected Welcome, got {reply:?}");
        };
        assert_eq!(version, VERSION);
        assert!(resident.contains(1));
        assert!(!resident.contains(2));
    }

    #[test]
    fn shutdown_and_unknown_frames() {
        let mut front = sim_front();
        let mut live = BTreeMap::new();
        let (reply, exit) = dispatch_with_store(&mut front, &mut live, Frame::Shutdown, "b", None);
        assert_eq!(reply, Frame::OkReply);
        assert_eq!(exit, Some(ConnExit::ShutdownRequested));
        let reply = rpc(&mut front, &mut live, Frame::OkReply);
        assert!(matches!(reply, Frame::ErrReply { .. }));
    }

    #[test]
    fn artifact_frames_serve_from_the_attached_store() {
        use crate::artifacts::synthetic_stack;

        let root = std::env::temp_dir()
            .join("caraserve-server-artifacts")
            .join(format!("dispatch-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let mut src = ArtifactStore::open(&root).unwrap();
        src.publish(1, 8, "tiny", &synthetic_stack(1, 16, 8)).unwrap();
        let (json, digest) = src.manifest_text(1).unwrap();
        let blob = src.manifest_of(1).unwrap().1.blobs[0].clone();
        let store = Mutex::new(src);

        let mut front = sim_front();
        let mut live = BTreeMap::new();
        let mut rpc = |f: Frame| {
            let (reply, exit) =
                dispatch_with_store(&mut front, &mut live, f, "b", Some(&store));
            assert!(exit.is_none());
            reply
        };

        // Manifest fetch: present and absent.
        assert_eq!(
            rpc(Frame::FetchManifest { adapter: 1 }),
            Frame::ManifestReply {
                found: true,
                json: json.clone(),
                digest: digest.clone(),
            }
        );
        assert_eq!(
            rpc(Frame::FetchManifest { adapter: 9 }),
            Frame::ManifestReply {
                found: false,
                json: String::new(),
                digest: String::new(),
            }
        );

        // Chunk fetch carries a verifiable per-chunk digest.
        let reply = rpc(Frame::FetchChunk {
            digest: blob.digest.clone(),
            offset: 0,
            len: 64,
        });
        let Frame::ChunkReply {
            bytes,
            chunk_digest,
            total,
            ..
        } = reply
        else {
            panic!("expected ChunkReply, got {reply:?}");
        };
        assert_eq!(total, blob.size);
        assert_eq!(hex_digest(&bytes), chunk_digest);

        // A corrupt pushed chunk is refused with an ErrReply.
        let reply = rpc(Frame::PushChunk {
            digest: "ab".repeat(32),
            offset: 0,
            total: 4,
            bytes: vec![1, 2, 3, 4],
            chunk_digest: "cd".repeat(32),
        });
        assert!(matches!(reply, Frame::ErrReply { .. }), "got {reply:?}");

        // ArtifactStat reports the store's blob census.
        let reply = rpc(Frame::ArtifactStat);
        let Frame::ArtifactStatReply { blobs, .. } = reply else {
            panic!("expected ArtifactStatReply, got {reply:?}");
        };
        assert_eq!(blobs, 5); // manifest + 4 tensors

        // Without a store every artifact frame is a typed refusal.
        let mut live2 = BTreeMap::new();
        let mut front2 = sim_front();
        let (reply, _) = dispatch_with_store(
            &mut front2,
            &mut live2,
            Frame::FetchManifest { adapter: 1 },
            "b",
            None,
        );
        assert!(matches!(reply, Frame::ErrReply { .. }));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn wire_error_display_is_reported_not_panicked() {
        // serve_connection path for a bad frame goes through err_reply;
        // exercise the formatting here.
        let reply = err_reply(&WireError::BadMagic { got: 7 });
        assert!(matches!(reply, Frame::ErrReply { .. }));
    }
}
