//! The backend host: serves the [`crate::remote::wire`] protocol over a
//! Unix socket in front of any [`ServingFront`] (native engine,
//! simulator, even a whole `ClusterFront`) — the process the
//! `caraserve backend` subcommand runs.
//!
//! The protocol is strict request-reply: every client frame gets
//! exactly one reply frame, and request events only flow inside the
//! reply to `Poll`. That keeps the host single-threaded (the front is
//! `&mut` throughout) and makes the router's deadline handling trivial.
//!
//! **Reconnect-with-state**: the listener loop serves one router
//! connection at a time; when a connection drops, in-flight requests
//! are cancelled and drained (their router failed them over already),
//! but the front itself — installed adapters, device residency, warm
//! caches — survives untouched. The next handshake's `Welcome` frame
//! reports the resident adapter set, which is what lets the router's
//! Probation→Healthy readmission skip re-installs when state survived
//! (and re-install from the registry when it did not).

use std::collections::BTreeMap;
use std::os::unix::net::UnixListener;
use std::path::Path;

use anyhow::Result;

use super::wire::{self, Frame, WireError, VERSION};
use crate::ipc::SocketChannel;
use crate::server::api::{RequestHandle, ServingFront};

/// Why [`serve_connection`] returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnExit {
    /// The peer disconnected (or its stream broke). The front's state
    /// survives; the listener loop accepts the next connection.
    Disconnected,
    /// The peer sent `Shutdown`: exit the listener loop.
    ShutdownRequested,
}

/// Bind the backend's listening socket, replacing a stale socket file
/// from a previous (killed) incarnation — exactly the restart path the
/// rejoin machinery exercises.
pub fn bind<P: AsRef<Path>>(path: P) -> Result<UnixListener> {
    let path = path.as_ref();
    if path.exists() {
        std::fs::remove_file(path)?;
    }
    Ok(UnixListener::bind(path)?)
}

/// Accept-and-serve loop: one router connection at a time, each served
/// by [`serve_connection`], until a `Shutdown` frame (or a listener
/// error). Adapter state persists across connections.
pub fn serve_listener(
    front: &mut dyn ServingFront,
    listener: &UnixListener,
    name: &str,
) -> Result<()> {
    loop {
        let (stream, _) = listener.accept()?;
        let mut chan = SocketChannel::from_stream(stream);
        match serve_connection(front, &mut chan, name) {
            ConnExit::Disconnected => continue,
            ConnExit::ShutdownRequested => return Ok(()),
        }
    }
}

/// Serve one connection's frames until the peer disconnects or asks
/// for shutdown. Never returns an error: a broken stream is a normal
/// [`ConnExit::Disconnected`] (the front outlives its connections).
pub fn serve_connection(
    front: &mut dyn ServingFront,
    chan: &mut SocketChannel,
    name: &str,
) -> ConnExit {
    // client request id → live handle; BTreeMap so Events frames list
    // requests in a deterministic order.
    let mut live: BTreeMap<u64, RequestHandle> = BTreeMap::new();
    loop {
        let bytes = match chan.recv_bytes() {
            Ok(b) => b,
            Err(_) => {
                quiesce(front, &mut live);
                return ConnExit::Disconnected;
            }
        };
        let (reply, exit) = match wire::decode(&bytes) {
            Ok(frame) => dispatch(front, &mut live, frame, name),
            // The socket layer delimits frames, so one undecodable
            // frame doesn't desynchronize the stream: report and keep
            // serving.
            Err(e) => (err_reply(&e), None),
        };
        if chan.send_bytes(&wire::encode(&reply)).is_err() {
            quiesce(front, &mut live);
            return ConnExit::Disconnected;
        }
        if let Some(exit) = exit {
            if exit == ConnExit::Disconnected {
                quiesce(front, &mut live);
            }
            return exit;
        }
    }
}

fn err_reply(e: &dyn std::fmt::Display) -> Frame {
    Frame::ErrReply {
        message: format!("{e}"),
    }
}

/// Handle one decoded frame; returns the reply and, when the
/// connection should end after it, the exit kind.
fn dispatch(
    front: &mut dyn ServingFront,
    live: &mut BTreeMap<u64, RequestHandle>,
    frame: Frame,
    name: &str,
) -> (Frame, Option<ConnExit>) {
    let reply = match frame {
        Frame::Hello { client: _ } => Frame::Welcome {
            version: VERSION,
            server: name.to_string(),
            resident: front.stats().adapters,
        },
        Frame::Submit { client_id, req } => {
            if live.contains_key(&client_id) {
                err_reply(&format_args!("client request id {client_id} already live"))
            } else {
                let handle = front.submit(req);
                // Synchronous lifecycle output (Admitted, or a terminal
                // Rejected) rides back on the reply so the router's
                // re-route loop sees refusals immediately.
                let events = handle.drain_events();
                let backend_id = handle.id();
                if !handle.is_terminal() {
                    live.insert(client_id, handle);
                }
                Frame::Submitted {
                    client_id,
                    backend_id,
                    events,
                }
            }
        }
        Frame::Poll => match front.poll() {
            Ok(progressed) => {
                let mut events = Vec::new();
                let mut done = Vec::new();
                for (&cid, handle) in live.iter() {
                    for ev in handle.drain_events() {
                        events.push((cid, ev));
                    }
                    if handle.is_terminal() {
                        done.push(cid);
                    }
                }
                for cid in done {
                    live.remove(&cid);
                }
                Frame::Events { events, progressed }
            }
            Err(e) => err_reply(&format_args!("{e:#}")),
        },
        Frame::Cancel { client_id } => Frame::CancelResult {
            live: match live.get(&client_id) {
                Some(handle) => front.cancel(handle.id()),
                None => false,
            },
        },
        Frame::Stats => Frame::StatsReply {
            stats: front.stats(),
        },
        Frame::Install { spec } => match front.install_adapter(&spec) {
            Ok(()) => Frame::OkReply,
            Err(e) => err_reply(&format_args!("{e:#}")),
        },
        Frame::Uninstall { adapter } => match front.uninstall_adapter(adapter) {
            Ok(()) => Frame::OkReply,
            Err(e) => err_reply(&format_args!("{e:#}")),
        },
        Frame::Prewarm { adapter } => match front.prewarm_adapter(adapter) {
            Ok(warmed) => Frame::PrewarmResult { warmed },
            Err(e) => err_reply(&format_args!("{e:#}")),
        },
        Frame::ColdStart => Frame::ColdStartReply {
            stats: front.cold_start_stats(),
        },
        Frame::Heartbeat { nonce } => Frame::HeartbeatAck { nonce },
        Frame::Shutdown => return (Frame::OkReply, Some(ConnExit::ShutdownRequested)),
        // Reply-direction frames arriving as requests are a peer bug.
        other => err_reply(&format_args!("unexpected frame {other:?}")),
    };
    (reply, None)
}

/// Cancel and drain every request the departed connection left in
/// flight, so the next connection (and the front's own queues) start
/// clean. Adapter state is deliberately untouched — that is the
/// "with-state" half of reconnect-with-state.
fn quiesce(front: &mut dyn ServingFront, live: &mut BTreeMap<u64, RequestHandle>) {
    for handle in live.values() {
        front.cancel(handle.id());
    }
    // Drive the cancellations to their terminal events; a front erroring
    // here has nothing further to drain.
    let _ = front.run_until_idle();
    for handle in live.values() {
        let _ = handle.drain_events();
    }
    live.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;
    use crate::server::api::{LifecycleState, RequestEvent, ServeRequest};
    use crate::sim::{GpuModel, ServingMode, SimFront, SimInstance};

    fn sim_front() -> SimFront {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::CaraServe, 32, 8, 64);
        let mut front = SimFront::new(inst, 512);
        front.register_adapter(1, 8);
        front
    }

    fn rpc(front: &mut dyn ServingFront, live: &mut BTreeMap<u64, RequestHandle>, f: Frame) -> Frame {
        let (reply, exit) = dispatch(front, live, f, "test-backend");
        assert!(exit.is_none());
        reply
    }

    #[test]
    fn submit_poll_drain_lifecycle() {
        let mut front = sim_front();
        let mut live = BTreeMap::new();
        let req = ServeRequest::new(1, vec![1, 2, 3]).max_new_tokens(4);
        let reply = rpc(
            &mut front,
            &mut live,
            Frame::Submit { client_id: 10, req },
        );
        let Frame::Submitted {
            client_id, events, ..
        } = reply
        else {
            panic!("expected Submitted, got {reply:?}");
        };
        assert_eq!(client_id, 10);
        assert_eq!(events, vec![RequestEvent::Admitted]);
        assert!(live.contains_key(&10));

        let mut seen = Vec::new();
        for _ in 0..64 {
            let reply = rpc(&mut front, &mut live, Frame::Poll);
            let Frame::Events { events, .. } = reply else {
                panic!("expected Events, got {reply:?}");
            };
            seen.extend(events);
            if live.is_empty() {
                break;
            }
        }
        assert!(live.is_empty(), "request never terminated");
        assert!(seen.iter().all(|(cid, _)| *cid == 10));
        assert_eq!(
            seen.iter().filter(|(_, ev)| ev.is_terminal()).count(),
            1,
            "exactly one terminal: {seen:?}"
        );
    }

    #[test]
    fn synchronous_rejection_rides_the_submit_reply() {
        let mut front = sim_front();
        let mut live = BTreeMap::new();
        // Adapter 9 is not registered: SimFront rejects at submit.
        let req = ServeRequest::new(9, vec![1]);
        let reply = rpc(&mut front, &mut live, Frame::Submit { client_id: 1, req });
        let Frame::Submitted { events, .. } = reply else {
            panic!("expected Submitted, got {reply:?}");
        };
        assert!(
            events.iter().any(|ev| ev.is_terminal()),
            "rejection must be synchronous: {events:?}"
        );
        assert!(live.is_empty(), "terminal request must not stay live");
    }

    #[test]
    fn quiesce_cancels_in_flight_and_preserves_adapters() {
        let mut front = sim_front();
        let mut live = BTreeMap::new();
        let req = ServeRequest::new(1, vec![1, 2]).max_new_tokens(8);
        rpc(&mut front, &mut live, Frame::Submit { client_id: 5, req });
        // Keep a view of the backend handle to check the terminal.
        let handle = live.get(&5).unwrap().clone();
        quiesce(&mut front, &mut live);
        assert!(live.is_empty());
        assert_eq!(handle.state(), LifecycleState::Cancelled);
        // The "state" in reconnect-with-state: adapters survive.
        assert!(front.stats().can_serve(1));
    }

    #[test]
    fn hello_reports_resident_adapters() {
        let mut front = sim_front();
        let mut live = BTreeMap::new();
        let reply = rpc(
            &mut front,
            &mut live,
            Frame::Hello {
                client: "router".into(),
            },
        );
        let Frame::Welcome {
            version, resident, ..
        } = reply
        else {
            panic!("expected Welcome, got {reply:?}");
        };
        assert_eq!(version, VERSION);
        assert!(resident.contains(1));
        assert!(!resident.contains(2));
    }

    #[test]
    fn shutdown_and_unknown_frames() {
        let mut front = sim_front();
        let mut live = BTreeMap::new();
        let (reply, exit) = dispatch(&mut front, &mut live, Frame::Shutdown, "b");
        assert_eq!(reply, Frame::OkReply);
        assert_eq!(exit, Some(ConnExit::ShutdownRequested));
        let reply = rpc(&mut front, &mut live, Frame::OkReply);
        assert!(matches!(reply, Frame::ErrReply { .. }));
    }

    #[test]
    fn wire_error_display_is_reported_not_panicked() {
        // serve_connection path for a bad frame goes through err_reply;
        // exercise the formatting here.
        let reply = err_reply(&WireError::BadMagic { got: 7 });
        assert!(matches!(reply, Frame::ErrReply { .. }));
    }
}
