//! # CaraServe — CPU-Assisted and Rank-Aware LoRA Serving (reproduction)
//!
//! This crate reproduces the system described in *"CaraServe: CPU-Assisted
//! and Rank-Aware LoRA Serving for Generative LLM Inference"* (cs.DC 2024)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the serving system: continuous batching engine,
//!   paged KV-cache manager, LoRA adapter registry/loader/device-cache,
//!   CPU-assisted LoRA engine (sync-free invocation, shared-memory IPC,
//!   profiling-guided parallelization), linear performance models, the
//!   rank-aware cluster scheduler (Algorithm 1), and a discrete-event
//!   cluster simulator used to regenerate every figure in the paper's
//!   evaluation.
//! - **L2 (python/compile/model.py)** — a tiny Llama-style forward pass
//!   with LoRA adaptation, AOT-lowered to HLO text at build time.
//! - **L1 (python/compile/kernels/)** — Pallas BGMV/MBGMV LoRA kernels
//!   (interpret mode), checked against a pure-jnp oracle.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and executes them
//! from Rust.
//!
//! ## Quick tour
//!
//! Serving is a streaming request lifecycle: build a
//! [`server::ServeRequest`] (adapter, prompt, sampling, priority,
//! optional SLO), `submit` it to any [`server::ServingFront`] backend,
//! and poll the returned [`server::RequestHandle`] for per-token
//! [`server::RequestEvent`]s — `Admitted → FirstToken → Token* →
//! Finished`, with `cancel()` and stop tokens honored mid-flight and
//! rejection surfaced as a terminal `Rejected` event.
//!
//! ```ignore
//! let handle = front.submit(
//!     ServeRequest::new(adapter, prompt)
//!         .max_new_tokens(32)
//!         .priority(Priority::Interactive)
//!         .slo(200.0, 50.0),
//! );
//! front.run_until_idle()?;
//! while let Some(event) = handle.poll_event() { /* stream tokens */ }
//! ```
//!
//! - [`server::ServingFront`] — the uniform, object-safe backend
//!   surface (submit / poll / cancel / stats), implemented by every
//!   front below so schedulers and drivers route against one interface.
//! - [`server::InferenceServer`] — the real single-server engine
//!   (base model + local LoRA repository + continuous batcher) over a
//!   [`runtime::Runtime`] backend: the PJRT executor for AOT artifacts,
//!   or the pure-Rust [`runtime::NativeRuntime`] on which CaraServe's
//!   CPU-assisted cold start runs for real (shm worker pool computing
//!   per-layer `xAB` while the adapter load window elapses, then the
//!   §4.3 handoff to the resident `bgmv` path). On the native runtime
//!   the engine runs **unified paged memory**: adapter weight stacks
//!   and request KV share one bounded page pool
//!   ([`server::kvcache::KvCacheManager`] +
//!   [`adapters::AdapterResidency`]), so idle adapters page out under
//!   pressure instead of pinning device memory — which is what lets a
//!   1,000+ adapter catalog serve from one engine (`--pool-pages`
//!   sizes the pool on the CLI).
//! - [`server::ClusterFront`] — the §5 rank-aware scheduler in front of
//!   N boxed `ServingFront` backends (real engines, simulators, or a
//!   mix): routes each request from registry rank + prompt length via a
//!   [`scheduler::Policy`], re-routes on backend refusal, fans out
//!   cancellation, and — being a `ServingFront` itself — drops into any
//!   driver written for one engine (`caraserve cluster` runs it live).
//!   It is also the fault boundary: backend panics are caught at the
//!   poll edge, a Healthy → Suspect → Down → Probation health machine
//!   (knobs in [`server::RetryPolicy`]) quarantines failing backends,
//!   in-flight requests fail over to a survivor with **bitwise-
//!   identical** client streams (the resume token is rebuilt from the
//!   client-side channel, never the dead backend), and when no healthy
//!   backend remains, admission sheds by priority class with typed
//!   `Overloaded` rejections instead of queueing into a dead cluster.
//!   Faults are injected deterministically by
//!   [`testkit::faults::ChaosFront`] — a `ServingFront` decorator
//!   executing a seeded [`testkit::faults::FaultPlan`]
//!   (`panic|error|die|stall|slow @ submit|poll|decode|load : n`) —
//!   and `caraserve chaos` drives the kill-mid-decode acceptance run
//!   against a no-fault oracle live.
//! - [`coordinator::Coordinator`] — the §3 global coordinator over a
//!   `ClusterFront`: computes registry-driven placements (popularity ×
//!   rank × slot pressure), pre-warms the hot head before traffic, and
//!   migrates hot adapters off saturated servers at runtime through the
//!   `ServingFront` management surface
//!   (`install_adapter` / `uninstall_adapter` / `prewarm_adapter`) —
//!   uninstall refuses while requests are in flight, so migrations
//!   never perturb a live token stream (`caraserve coordinator`
//!   compares static vs coordinated placement live). The control plane
//!   is crash-restartable: `save_state` snapshots the
//!   [`scheduler::registry::GlobalRegistry`] and `load_state` rebuilds
//!   an identically-placed coordinator over fresh backends.
//! - [`sim::SimFront`] — the discrete-event simulator behind the same
//!   API; [`sim::Simulation`] runs calibrated cluster experiments.
//! - [`scheduler::RankAwareScheduler`] — Algorithm 1 over a cluster,
//!   consuming the [`scheduler::ServerStats`] every front produces:
//!   real eligibility data (local adapter set, prompt capacity, KV
//!   headroom, preemptions) plus the running/queued rank lists.
//! - [`cpu_lora::CpuLoraEngine`] — the CPU-assisted prefill engine.
//!
//! ## Distributed serving
//!
//! The [`remote`] module splits the request plane across OS processes
//! without changing any routing code: `caraserve backend --socket
//! /tmp/b0.sock` hosts an engine behind the [`remote::wire`] frame
//! protocol, the router's [`remote::RemoteFront`] speaks it as an
//! ordinary `ServingFront` (so `ClusterFront`/`Coordinator` route
//! across processes unchanged, including PR 8 failover — plus
//! *reconnect-with-state*: a rebooted backend re-handshakes, reports
//! its resident adapters, and is readmitted without re-install when
//! they survived, or re-installed from the
//! [`scheduler::registry::GlobalRegistry`] when they did not), and
//! `caraserve serve --remote /tmp/b0.sock,/tmp/b1.sock --http
//! 127.0.0.1:8090` exposes the cluster over HTTP/1.1: `POST
//! /v1/requests` streams token events as chunked JSON lines,
//! `DELETE /v1/requests/<id>` cancels, `GET /v1/stats` aggregates
//! ([`remote::HttpGateway`], zero new dependencies).
//!
//! ## Adapter artifacts
//!
//! The [`artifacts`] module is the deployment pipeline the distributed
//! tier installs from: an [`artifacts::ArtifactStore`] is a directory of
//! digest-addressed blobs (`blobs/<sha256>`, hand-rolled
//! [`artifacts::sha256`] on `std`) indexed by hand-rolled-JSON
//! [`artifacts::Manifest`]s (adapter id, rank, base model, per-tensor
//! blob digests + sizes — the OCI artifact shape). Content addressing
//! gives dedup for free (two adapters sharing a tensor store it once),
//! every read re-verifies bytes against their digest, and
//! [`artifacts::ArtifactStore::gc`] refcounts blobs so a placed adapter
//! can never lose its weights. `caraserve artifacts
//! seed|push|pull|verify|gc` drives the pipeline from the CLI, the
//! engine sources `install_adapter` weights from an attached store
//! (falling back to synthetic seeding only when no manifest covers the
//! adapter — counted by [`server::InstallSourceStats`]), and
//! [`remote::RemoteFront`] streams manifests + chunked, per-chunk-
//! digest-verified blobs over the wire so coordinator migrations move
//! *real* weights between processes, overlapping the transfer with the
//! CPU-assist prefill window so target TTFT is `max(transfer, prefill)`
//! rather than their sum.
//!
//! See `examples/quickstart.rs` for a compact end-to-end run.
//!
//! The tree gates itself with `caraserve lint` ([`analysis`]): every
//! `unsafe` carries a `// SAFETY:` argument, every `Ordering::Relaxed`
//! an `// ORDERING:` justification, hot paths stay panic-free, and
//! extern path roots must resolve to declared crates. The concurrent
//! protocols are additionally model-checked by the bounded
//! interleaving explorer in [`testkit::interleave`].

// Crate-wide unsafe policy (mirrored by the `caraserve lint`
// unsafe-op-deny rule and clippy's undocumented_unsafe_blocks):
// unsafe operations inside `unsafe fn` need explicit blocks, and every
// unsafe block needs a written safety argument.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod adapters;
pub mod analysis;
pub mod artifacts;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod cpu_lora;
// The IPC and runtime hot paths must not panic on request data: no
// bare unwrap (the mutex-poisoning `.expect` idiom is the exception,
// also tolerated by the in-repo hot-unwrap lint).
#[warn(clippy::unwrap_used)]
pub mod ipc;
pub mod kernels;
pub mod model;
pub mod perfmodel;
pub mod remote;
#[warn(clippy::unwrap_used)]
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod testkit;
pub mod util;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
