//! # CaraServe — CPU-Assisted and Rank-Aware LoRA Serving (reproduction)
//!
//! This crate reproduces the system described in *"CaraServe: CPU-Assisted
//! and Rank-Aware LoRA Serving for Generative LLM Inference"* (cs.DC 2024)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the serving system: continuous batching engine,
//!   paged KV-cache manager, LoRA adapter registry/loader/device-cache,
//!   CPU-assisted LoRA engine (sync-free invocation, shared-memory IPC,
//!   profiling-guided parallelization), linear performance models, the
//!   rank-aware cluster scheduler (Algorithm 1), and a discrete-event
//!   cluster simulator used to regenerate every figure in the paper's
//!   evaluation.
//! - **L2 (python/compile/model.py)** — a tiny Llama-style forward pass
//!   with LoRA adaptation, AOT-lowered to HLO text at build time.
//! - **L1 (python/compile/kernels/)** — Pallas BGMV/MBGMV LoRA kernels
//!   (interpret mode), checked against a pure-jnp oracle.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and executes them
//! from Rust.
//!
//! ## Quick tour
//!
//! - [`server::InferenceServer`] — a single LLM inference server
//!   (base model + local LoRA repository + continuous batcher).
//! - [`scheduler::RankAwareScheduler`] — Algorithm 1 over a cluster.
//! - [`sim::Simulation`] — discrete-event cluster simulator calibrated to
//!   the paper's A10/A100 latency shapes.
//! - [`cpu_lora::CpuLoraEngine`] — the CPU-assisted prefill engine.
//!
//! See `examples/quickstart.rs` for a 30-line end-to-end run.

pub mod adapters;
pub mod bench;
pub mod config;
pub mod cpu_lora;
pub mod ipc;
pub mod kernels;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod testkit;
pub mod util;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
