//! Adapter management on one inference server (paper §3): the host-memory
//! repository (every adapter's weights + metadata), the bounded device
//! slot cache (which adapters are GPU-resident), the cold-start loader
//! model, and the [`AsyncLoader`] that tracks in-flight host→device load
//! windows for the CPU-assisted path (§4.3: requests keep decoding via
//! CPU LoRA until their adapter's load deadline passes, then hand off to
//! the resident GPU path).
//!
//! The functional PJRT path bakes `LORA_SLOTS` adapter stacks into the
//! artifacts, so "loading adapter X" maps X onto a device slot; the
//! native runtime installs real weight stacks per slot at load
//! completion. The host→device transfer itself is modeled latency (this
//! testbed has no discrete device — see DESIGN.md §4 substitutions).
//!
//! Since the unified-paging refactor, the native engine replaces the
//! fixed [`DeviceSlotCache`] with [`AdapterResidency`]: residency is
//! backed by rank-proportional pages in the shared
//! [`crate::server::kvcache::KvCacheManager`] pool (acquire = page-in,
//! evict = page release, prewarm = pre-paging), and the slot array
//! becomes just a bound on *simultaneously executing* adapters. Idle
//! adapters are evicted by decayed-popularity LRU under KV pressure
//! ([`AdapterResidency::victim`]); the PJRT path keeps the fixed
//! [`DeviceSlotCache`] (its artifacts bake one stack per slot).
//! [`flatten_stack`] / [`stack_from_flat`] are the lossless bridges
//! between a `[AdapterWeights; 4]` Q/K/V/O stack and the flat f32 run
//! the pool pages hold.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::config::GpuSpec;
use crate::kernels::bgmv::AdapterWeights;
use crate::model::{LlamaConfig, LoraSpec};

/// Errors from adapter/slot management.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdapterError {
    /// A [`DeviceSlotCache`] cannot be built with zero slots: `acquire`
    /// would index an empty LRU and `acquire_fixed` would divide by zero.
    NoSlots,
}

impl std::fmt::Display for AdapterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdapterError::NoSlots => write!(f, "device slot cache needs ≥ 1 slot"),
        }
    }
}

impl std::error::Error for AdapterError {}

/// Host-memory adapter repository: id → spec (weights stay in the
/// cpu_lora [`crate::cpu_lora::AdapterTable`] for compute).
#[derive(Default)]
pub struct HostRepository {
    specs: HashMap<u64, LoraSpec>,
}

impl HostRepository {
    /// Empty repository.
    pub fn new() -> Self {
        Self::default()
    }

    /// Install an adapter spec.
    pub fn install(&mut self, spec: LoraSpec) {
        self.specs.insert(spec.id, spec);
    }

    /// Look up.
    pub fn get(&self, id: u64) -> Option<&LoraSpec> {
        self.specs.get(&id)
    }

    /// Remove an adapter spec (runtime uninstall), returning it if it
    /// was installed.
    pub fn remove(&mut self, id: u64) -> Option<LoraSpec> {
        self.specs.remove(&id)
    }

    /// All installed adapter ids (unsorted — callers needing order sort,
    /// e.g. `AdapterSet::only` does).
    pub fn ids(&self) -> Vec<u64> {
        self.specs.keys().copied().collect()
    }

    /// Count.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }
}

/// Result of acquiring a device slot for an adapter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotAcquire {
    /// The device slot the adapter occupies.
    pub slot: usize,
    /// True if the adapter had to be loaded (cold start).
    pub cold: bool,
}

/// Bounded device slot cache with LRU eviction: which adapters are
/// resident in the GPU-side LoRA stacks.
///
/// Stamp-based LRU: `touch` is O(1) (bump a per-slot use stamp); the
/// O(n) victim scan runs only on a cold `acquire` — the previous
/// `Vec::position + remove` implementation paid O(n) on every hit.
pub struct DeviceSlotCache {
    /// slot → adapter id.
    slots: Vec<Option<u64>>,
    /// adapter id → slot.
    index: HashMap<u64, usize>,
    /// slot → last-use stamp (smaller = older).
    stamps: Vec<u64>,
    clock: u64,
}

impl DeviceSlotCache {
    /// A cache with `n_slots` device slots. Zero slots is a construction
    /// error: every acquire on such a cache would be unanswerable.
    pub fn new(n_slots: usize) -> Result<DeviceSlotCache, AdapterError> {
        if n_slots == 0 {
            return Err(AdapterError::NoSlots);
        }
        Ok(DeviceSlotCache {
            slots: vec![None; n_slots],
            index: HashMap::new(),
            stamps: vec![0; n_slots],
            clock: 0,
        })
    }

    /// Number of slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Adapter in a slot.
    pub fn occupant(&self, slot: usize) -> Option<u64> {
        self.slots[slot]
    }

    /// Is an adapter resident?
    pub fn resident(&self, adapter: u64) -> bool {
        self.index.contains_key(&adapter)
    }

    /// The slot an adapter occupies, if resident.
    pub fn slot_of(&self, adapter: u64) -> Option<usize> {
        self.index.get(&adapter).copied()
    }

    /// The slot `acquire_fixed` would map this adapter to (without
    /// acquiring) — lets admission control detect slot collisions before
    /// committing a batch.
    pub fn fixed_slot(&self, adapter: u64) -> usize {
        (adapter % self.slots.len() as u64) as usize
    }

    fn touch(&mut self, slot: usize) {
        self.clock += 1;
        self.stamps[slot] = self.clock;
    }

    /// Acquire a slot for `adapter`: hit if resident, otherwise evict the
    /// LRU slot and mark cold.
    pub fn acquire(&mut self, adapter: u64) -> SlotAcquire {
        if let Some(&slot) = self.index.get(&adapter) {
            self.touch(slot);
            return SlotAcquire { slot, cold: false };
        }
        // Victim: the least-recently-stamped slot (empty slots have stamp
        // 0 and are taken first).
        let slot = (0..self.stamps.len())
            .min_by_key(|&s| self.stamps[s])
            .expect("≥ 1 slot by construction");
        if let Some(old) = self.slots[slot] {
            self.index.remove(&old);
        }
        self.slots[slot] = Some(adapter);
        self.index.insert(adapter, slot);
        self.touch(slot);
        SlotAcquire { slot, cold: true }
    }

    /// Evict `adapter` from its slot (runtime uninstall), returning the
    /// freed slot. The slot's stamp resets to 0 so it is the first LRU
    /// victim. No-op (`None`) when the adapter is not resident.
    pub fn evict(&mut self, adapter: u64) -> Option<usize> {
        let slot = self.index.remove(&adapter)?;
        self.slots[slot] = None;
        self.stamps[slot] = 0;
        Some(slot)
    }

    /// Acquire a *fixed* slot for `adapter` (the functional PJRT path:
    /// the artifacts bake one weight stack per slot, so an adapter must
    /// always land in the same slot for its outputs to be deterministic).
    /// Returns `cold = true` when the slot's occupant changes — the
    /// moment a real system would pay the host→device transfer.
    pub fn acquire_fixed(&mut self, adapter: u64) -> SlotAcquire {
        let slot = self.fixed_slot(adapter);
        let cold = self.slots[slot] != Some(adapter);
        if cold {
            if let Some(old) = self.slots[slot] {
                self.index.remove(&old);
            }
            self.slots[slot] = Some(adapter);
            self.index.insert(adapter, slot);
        }
        self.touch(slot);
        SlotAcquire { slot, cold }
    }
}

/// Decay factor applied per residency-clock tick when aging an
/// adapter's popularity score (see [`AdapterResidency::touch`]). Chosen
/// so a once-hot adapter outlives a few intervening touches but loses to
/// steadily-used ones within ~20 ticks.
const RESIDENCY_DECAY: f64 = 0.9;

/// Paged adapter residency: which adapters currently hold weight pages
/// in the unified [`crate::server::kvcache::KvCacheManager`] pool.
///
/// Unlike [`DeviceSlotCache`], this layer owns no memory itself — the
/// pool does. The slot array only bounds how many adapters can be
/// resident at once (= the runtime's LoRA slot count, since each
/// resident adapter still needs a runtime slot to execute from) and
/// carries the eviction metadata: a logical clock for LRU stamps and a
/// per-slot EWMA popularity score decayed by clock age, so
/// [`AdapterResidency::victim`] picks the *coldest idle* adapter, not
/// merely the least recent. The engine supplies the busy predicate
/// (queued/running/loading adapters are never victims — PR 5 guards).
pub struct AdapterResidency {
    /// slot → adapter id.
    slots: Vec<Option<u64>>,
    /// adapter id → slot.
    index: HashMap<u64, usize>,
    /// slot → last-touch stamp (smaller = older; 0 = never/freed).
    stamps: Vec<u64>,
    /// slot → EWMA popularity as of its stamp (decays with clock age).
    scores: Vec<f64>,
    clock: u64,
}

impl AdapterResidency {
    /// A residency tracker bounded to `n_slots` simultaneously-resident
    /// adapters. Zero slots is a construction error, as for
    /// [`DeviceSlotCache::new`].
    pub fn new(n_slots: usize) -> Result<AdapterResidency, AdapterError> {
        if n_slots == 0 {
            return Err(AdapterError::NoSlots);
        }
        Ok(AdapterResidency {
            slots: vec![None; n_slots],
            index: HashMap::new(),
            stamps: vec![0; n_slots],
            scores: vec![0.0; n_slots],
            clock: 0,
        })
    }

    /// Maximum simultaneously-resident adapters.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Number of resident adapters.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True when no adapter is resident.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Adapter in a slot.
    pub fn occupant(&self, slot: usize) -> Option<u64> {
        self.slots[slot]
    }

    /// Is an adapter resident (holding pool pages)?
    pub fn resident(&self, adapter: u64) -> bool {
        self.index.contains_key(&adapter)
    }

    /// The slot a resident adapter executes from.
    pub fn slot_of(&self, adapter: u64) -> Option<usize> {
        self.index.get(&adapter).copied()
    }

    /// All resident adapter ids, ascending.
    pub fn residents(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.index.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// True when `insert` would succeed without an eviction.
    pub fn has_free_slot(&self) -> bool {
        self.index.len() < self.slots.len()
    }

    /// A slot's popularity score decayed to the current clock.
    fn decayed(&self, slot: usize) -> f64 {
        let age = self.clock.saturating_sub(self.stamps[slot]);
        // Exponent saturates: past ~7000 ticks of idleness the score is
        // already denormal-zero, so clamping loses nothing.
        self.scores[slot] * RESIDENCY_DECAY.powi(age.min(i32::MAX as u64) as i32)
    }

    /// Record a use of a resident adapter: bumps the logical clock, ages
    /// the slot's score to now, and adds 1. No-op for non-residents.
    pub fn touch(&mut self, adapter: u64) {
        if let Some(&slot) = self.index.get(&adapter) {
            self.clock += 1;
            let aged = self.decayed(slot);
            self.scores[slot] = aged + 1.0;
            self.stamps[slot] = self.clock;
        }
    }

    /// Make `adapter` resident in the lowest free slot (deterministic),
    /// with an initial score of 1. Returns the slot, or the existing one
    /// if already resident, or `None` when every slot is occupied — the
    /// caller must `evict` a [`victim`](Self::victim) first.
    pub fn insert(&mut self, adapter: u64) -> Option<usize> {
        if let Some(&slot) = self.index.get(&adapter) {
            return Some(slot);
        }
        let slot = self.slots.iter().position(|s| s.is_none())?;
        self.slots[slot] = Some(adapter);
        self.index.insert(adapter, slot);
        self.clock += 1;
        self.stamps[slot] = self.clock;
        self.scores[slot] = 1.0;
        Some(slot)
    }

    /// Drop an adapter's residency, returning its freed slot (the caller
    /// releases the pool pages and clears the runtime slot). `None` when
    /// not resident.
    pub fn evict(&mut self, adapter: u64) -> Option<usize> {
        let slot = self.index.remove(&adapter)?;
        self.slots[slot] = None;
        self.stamps[slot] = 0;
        self.scores[slot] = 0.0;
        Some(slot)
    }

    /// The eviction candidate: the non-busy resident with the lowest
    /// decayed popularity (ties → older stamp, then smaller id, so the
    /// choice is deterministic). `None` when every resident is busy.
    pub fn victim(&self, busy: impl Fn(u64) -> bool) -> Option<u64> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(slot, occ)| occ.map(|a| (slot, a)))
            .filter(|&(_, a)| !busy(a))
            .min_by(|&(s1, a1), &(s2, a2)| {
                self.decayed(s1)
                    .total_cmp(&self.decayed(s2))
                    .then(self.stamps[s1].cmp(&self.stamps[s2]))
                    .then(a1.cmp(&a2))
            })
            .map(|(_, a)| a)
    }
}

/// Flatten a Q/K/V/O adapter stack into the single f32 run the unified
/// pool pages hold: for each target in order, the A matrix
/// (`hidden × rank`) then the B matrix (`rank × hidden`) — total
/// `8 · hidden · rank` elements. Inverse of [`stack_from_flat`].
pub fn flatten_stack(stack: &[AdapterWeights; 4]) -> Vec<f32> {
    let total: usize = stack.iter().map(|w| w.a.len() + w.b.len()).sum();
    let mut out = Vec::with_capacity(total);
    for w in stack {
        out.extend_from_slice(&w.a);
        out.extend_from_slice(&w.b);
    }
    out
}

/// Rebuild the Q/K/V/O stack from a flat pool run written by
/// [`flatten_stack`]. The copies are value-identical, so token streams
/// computed from a re-paged stack are bitwise-equal to the original's.
///
/// # Panics
/// If `flat.len() != 8 * hidden * rank` (a corrupted residency record).
pub fn stack_from_flat(flat: &[f32], hidden: usize, rank: usize) -> [AdapterWeights; 4] {
    let a_len = hidden * rank;
    let per = 2 * a_len;
    assert_eq!(
        flat.len(),
        4 * per,
        "flat adapter run must hold 4 (A,B) pairs of hidden={hidden} rank={rank}"
    );
    std::array::from_fn(|t| {
        let base = t * per;
        AdapterWeights {
            rank,
            a: flat[base..base + a_len].to_vec(),
            b: flat[base + a_len..base + per].to_vec(),
            h1: hidden,
            h2: hidden,
        }
    })
}

/// Tracks per-adapter in-flight host→device load windows with completion
/// deadlines (§4.3). The engine `begin`s a load on a cold CaraServe
/// admit, keeps serving the adapter through the CPU-LoRA path while
/// [`AsyncLoader::loading`] holds, and `poll`s each iteration to learn
/// which adapters finished and may hand off to the resident GPU path.
#[derive(Debug, Default)]
pub struct AsyncLoader {
    deadlines: HashMap<u64, Instant>,
}

impl AsyncLoader {
    /// No loads in flight.
    pub fn new() -> AsyncLoader {
        AsyncLoader::default()
    }

    /// Begin (or observe an already-running) load of `adapter` taking
    /// `window` from now. Returns the completion deadline. A second
    /// `begin` for an adapter already in flight keeps the *earlier*
    /// deadline — the transfer started then.
    pub fn begin(&mut self, adapter: u64, window: Duration) -> Instant {
        let candidate = Instant::now() + window;
        let deadline = self.deadlines.entry(adapter).or_insert(candidate);
        if *deadline > candidate {
            *deadline = candidate;
        }
        *deadline
    }

    /// Is this adapter's load still in flight?
    pub fn loading(&self, adapter: u64) -> bool {
        self.deadlines.contains_key(&adapter)
    }

    /// Time remaining on an in-flight load (zero if past deadline).
    pub fn remaining(&self, adapter: u64, now: Instant) -> Option<Duration> {
        self.deadlines
            .get(&adapter)
            .map(|&d| d.saturating_duration_since(now))
    }

    /// The nearest completion deadline among in-flight loads.
    pub fn earliest_deadline(&self) -> Option<Instant> {
        self.deadlines.values().min().copied()
    }

    /// Abort an in-flight load (runtime uninstall of a still-loading
    /// adapter). Returns true if a load was actually in flight.
    pub fn cancel(&mut self, adapter: u64) -> bool {
        self.deadlines.remove(&adapter).is_some()
    }

    /// Remove and return every adapter whose deadline has passed.
    pub fn poll(&mut self, now: Instant) -> Vec<u64> {
        let done: Vec<u64> = self
            .deadlines
            .iter()
            .filter(|(_, &d)| d <= now)
            .map(|(&a, _)| a)
            .collect();
        for a in &done {
            self.deadlines.remove(a);
        }
        done
    }

    /// Adapters currently loading.
    pub fn adapters(&self) -> impl Iterator<Item = u64> + '_ {
        self.deadlines.keys().copied()
    }

    /// Number of in-flight loads.
    pub fn len(&self) -> usize {
        self.deadlines.len()
    }

    /// True when nothing is loading.
    pub fn is_empty(&self) -> bool {
        self.deadlines.is_empty()
    }
}

/// Cold-start latency model: what loading an adapter host→device costs
/// (Fig 3-Right).
#[derive(Debug, Clone)]
pub struct LoaderModel {
    pub cfg: LlamaConfig,
    pub gpu: GpuSpec,
    /// Scale factor applied to the modeled time (lets the tiny-model
    /// functional path use proportionally tiny delays).
    pub scale: f64,
}

impl LoaderModel {
    /// Standard model.
    pub fn new(cfg: LlamaConfig, gpu: GpuSpec) -> LoaderModel {
        LoaderModel {
            cfg,
            gpu,
            scale: 1.0,
        }
    }

    /// Modeled load time for an adapter (seconds).
    pub fn load_time(&self, spec: &LoraSpec) -> f64 {
        self.gpu.h2d_time(spec.weight_bytes(&self.cfg)) * self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repository_install_get() {
        let mut repo = HostRepository::new();
        repo.install(LoraSpec::standard(1, 64, "llama2-7b"));
        repo.install(LoraSpec::standard(2, 8, "llama2-7b"));
        assert_eq!(repo.len(), 2);
        assert_eq!(repo.get(1).unwrap().rank, 64);
        assert!(repo.get(3).is_none());
    }

    #[test]
    fn slot_cache_hit_and_miss() {
        let mut c = DeviceSlotCache::new(2).unwrap();
        let a = c.acquire(10);
        assert!(a.cold);
        let b = c.acquire(10);
        assert!(!b.cold);
        assert_eq!(a.slot, b.slot);
        assert_eq!(c.slot_of(10), Some(a.slot));
        assert_eq!(c.slot_of(99), None);
    }

    #[test]
    fn zero_slot_cache_is_a_typed_error() {
        assert_eq!(DeviceSlotCache::new(0).unwrap_err(), AdapterError::NoSlots);
        assert!(AdapterError::NoSlots.to_string().contains("slot"));
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = DeviceSlotCache::new(2).unwrap();
        let s1 = c.acquire(1).slot;
        let _s2 = c.acquire(2).slot;
        c.acquire(1); // 1 now MRU; 2 is LRU
        let s3 = c.acquire(3); // evicts 2
        assert!(s3.cold);
        assert!(c.resident(1));
        assert!(!c.resident(2));
        assert!(c.resident(3));
        assert_ne!(s3.slot, s1);
    }

    #[test]
    fn distinct_adapters_get_distinct_slots_until_full() {
        let mut c = DeviceSlotCache::new(4).unwrap();
        let slots: Vec<usize> = (0..4).map(|i| c.acquire(i).slot).collect();
        let mut sorted = slots.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
    }

    #[test]
    fn evict_frees_the_slot_for_immediate_reuse() {
        let mut c = DeviceSlotCache::new(2).unwrap();
        let s = c.acquire(10).slot;
        c.acquire(20);
        assert_eq!(c.evict(10), Some(s));
        assert!(!c.resident(10));
        assert_eq!(c.occupant(s), None);
        assert_eq!(c.evict(10), None); // already gone
        // The freed slot (stamp 0) is the next LRU victim.
        assert_eq!(c.acquire(30).slot, s);
    }

    #[test]
    fn loader_cancel_aborts_in_flight_loads() {
        let mut l = AsyncLoader::new();
        l.begin(7, Duration::from_secs(10));
        assert!(l.loading(7));
        assert!(l.cancel(7));
        assert!(!l.loading(7));
        assert!(!l.cancel(7));
        assert!(l.is_empty());
    }

    #[test]
    fn repository_remove() {
        let mut repo = HostRepository::new();
        repo.install(LoraSpec::standard(1, 64, "llama2-7b"));
        assert_eq!(repo.remove(1).unwrap().rank, 64);
        assert!(repo.remove(1).is_none());
        assert!(repo.is_empty());
    }

    #[test]
    fn acquire_fixed_is_deterministic_and_tracks_residency() {
        let mut c = DeviceSlotCache::new(8).unwrap();
        let a = c.acquire_fixed(3);
        assert!(a.cold);
        assert_eq!(a.slot, 3);
        assert!(!c.acquire_fixed(3).cold); // warm now
        // Adapter 11 collides on slot 3 → evicts 3.
        let b = c.acquire_fixed(11);
        assert!(b.cold);
        assert_eq!(b.slot, 3);
        assert!(c.acquire_fixed(3).cold); // 3 was evicted
        assert_eq!(c.fixed_slot(11), 3); // non-mutating mapping
    }

    #[test]
    fn async_loader_deadlines_and_poll() {
        let mut l = AsyncLoader::new();
        assert!(l.is_empty());
        let d1 = l.begin(7, Duration::from_millis(50));
        assert!(l.loading(7));
        assert!(!l.loading(8));
        assert_eq!(l.len(), 1);
        // Re-begin keeps the earlier deadline.
        let d2 = l.begin(7, Duration::from_secs(10));
        assert_eq!(d1, d2);
        // Not yet due.
        assert!(l.poll(Instant::now()).is_empty());
        assert!(l.remaining(7, Instant::now()).unwrap() <= Duration::from_millis(50));
        assert_eq!(l.earliest_deadline(), Some(d1));
        // Past the deadline it completes exactly once.
        let later = Instant::now() + Duration::from_millis(60);
        assert_eq!(l.poll(later), vec![7]);
        assert!(l.poll(later).is_empty());
        assert!(!l.loading(7));
    }

    #[test]
    fn residency_insert_lowest_free_slot_and_bounds() {
        let mut r = AdapterResidency::new(2).unwrap();
        assert!(r.is_empty());
        assert!(r.has_free_slot());
        assert_eq!(r.insert(10), Some(0));
        assert_eq!(r.insert(20), Some(1));
        assert_eq!(r.insert(10), Some(0)); // idempotent
        assert_eq!(r.len(), 2);
        assert!(!r.has_free_slot());
        assert_eq!(r.insert(30), None); // full: caller must evict first
        assert_eq!(r.slot_of(20), Some(1));
        assert_eq!(r.occupant(0), Some(10));
        assert_eq!(r.residents(), vec![10, 20]);
        // Evict frees the lowest slot for the next insert.
        assert_eq!(r.evict(10), Some(0));
        assert_eq!(r.evict(10), None);
        assert_eq!(r.insert(30), Some(0));
        assert_eq!(AdapterResidency::new(0).unwrap_err(), AdapterError::NoSlots);
    }

    #[test]
    fn residency_victim_prefers_cold_and_skips_busy() {
        let mut r = AdapterResidency::new(3).unwrap();
        r.insert(1);
        r.insert(2);
        r.insert(3);
        // Heat 1 with repeated touches; touch 3 once more; 2 stays cold.
        for _ in 0..5 {
            r.touch(1);
        }
        r.touch(3);
        assert_eq!(r.victim(|_| false), Some(2));
        // Busy guard: with 2 busy the next-coldest (3) is the victim.
        assert_eq!(r.victim(|a| a == 2), Some(3));
        // All busy → no victim, never evict a working adapter.
        assert_eq!(r.victim(|_| true), None);
    }

    #[test]
    fn residency_decay_ages_out_past_popularity() {
        let mut r = AdapterResidency::new(2).unwrap();
        r.insert(1);
        r.insert(2);
        // 1 is hot early…
        for _ in 0..10 {
            r.touch(1);
        }
        // …then 2 keeps working while 1 goes idle. After enough ticks
        // 1's decayed score drops below 2's steady score.
        for _ in 0..40 {
            r.touch(2);
        }
        assert_eq!(r.victim(|_| false), Some(1));
    }

    #[test]
    fn flatten_stack_round_trips_bitwise() {
        let (hidden, rank) = (16usize, 4usize);
        let stack: [AdapterWeights; 4] =
            std::array::from_fn(|t| AdapterWeights::synthetic(7 * 31 + t as u64, hidden, hidden, rank));
        let flat = flatten_stack(&stack);
        assert_eq!(flat.len(), 8 * hidden * rank);
        let back = stack_from_flat(&flat, hidden, rank);
        for (orig, re) in stack.iter().zip(back.iter()) {
            assert_eq!(orig.rank, re.rank);
            assert_eq!(orig.h1, re.h1);
            assert_eq!(orig.h2, re.h2);
            // Bitwise equality — the contract the stream oracle rests on.
            assert!(orig.a.iter().zip(&re.a).all(|(x, y)| x.to_bits() == y.to_bits()));
            assert!(orig.b.iter().zip(&re.b).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn loader_model_scales_with_rank() {
        let m = LoaderModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10());
        let t8 = m.load_time(&LoraSpec::standard(1, 8, "llama2-7b"));
        let t64 = m.load_time(&LoraSpec::standard(2, 64, "llama2-7b"));
        assert!(t64 > t8);
        // Fig 3-Right band: tens of ms for rank 64.
        assert!((15e-3..30e-3).contains(&t64), "t64={t64}");
    }
}
