//! Adapter management on one inference server (paper §3): the host-memory
//! repository (every adapter's weights + metadata), the bounded device
//! slot cache (which adapters are GPU-resident), and the cold-start
//! loader model.
//!
//! The functional PJRT path bakes `LORA_SLOTS` adapter stacks into the
//! artifacts, so "loading adapter X" maps X onto a device slot with LRU
//! eviction; the host→device transfer itself is modeled latency (this
//! testbed has no discrete device — see DESIGN.md §4 substitutions).

use std::collections::HashMap;

use crate::config::GpuSpec;
use crate::model::{LlamaConfig, LoraSpec};

/// Host-memory adapter repository: id → spec (weights stay in the
/// cpu_lora [`crate::cpu_lora::AdapterTable`] for compute).
#[derive(Default)]
pub struct HostRepository {
    specs: HashMap<u64, LoraSpec>,
}

impl HostRepository {
    /// Empty repository.
    pub fn new() -> Self {
        Self::default()
    }

    /// Install an adapter spec.
    pub fn install(&mut self, spec: LoraSpec) {
        self.specs.insert(spec.id, spec);
    }

    /// Look up.
    pub fn get(&self, id: u64) -> Option<&LoraSpec> {
        self.specs.get(&id)
    }

    /// Count.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }
}

/// Result of acquiring a device slot for an adapter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotAcquire {
    /// The device slot the adapter occupies.
    pub slot: usize,
    /// True if the adapter had to be loaded (cold start).
    pub cold: bool,
}

/// Bounded device slot cache with LRU eviction: which adapters are
/// resident in the GPU-side LoRA stacks.
pub struct DeviceSlotCache {
    /// slot → adapter id.
    slots: Vec<Option<u64>>,
    /// adapter id → slot.
    index: HashMap<u64, usize>,
    /// LRU order: least recent first.
    lru: Vec<usize>,
}

impl DeviceSlotCache {
    /// A cache with `n_slots` device slots.
    pub fn new(n_slots: usize) -> DeviceSlotCache {
        DeviceSlotCache {
            slots: vec![None; n_slots],
            index: HashMap::new(),
            lru: (0..n_slots).collect(),
        }
    }

    /// Number of slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Adapter in a slot.
    pub fn occupant(&self, slot: usize) -> Option<u64> {
        self.slots[slot]
    }

    /// Is an adapter resident?
    pub fn resident(&self, adapter: u64) -> bool {
        self.index.contains_key(&adapter)
    }

    fn touch(&mut self, slot: usize) {
        if let Some(pos) = self.lru.iter().position(|&s| s == slot) {
            self.lru.remove(pos);
        }
        self.lru.push(slot);
    }

    /// Acquire a slot for `adapter`: hit if resident, otherwise evict the
    /// LRU slot and mark cold.
    pub fn acquire(&mut self, adapter: u64) -> SlotAcquire {
        if let Some(&slot) = self.index.get(&adapter) {
            self.touch(slot);
            return SlotAcquire { slot, cold: false };
        }
        let slot = self.lru[0];
        if let Some(old) = self.slots[slot] {
            self.index.remove(&old);
        }
        self.slots[slot] = Some(adapter);
        self.index.insert(adapter, slot);
        self.touch(slot);
        SlotAcquire { slot, cold: true }
    }

    /// Acquire a *fixed* slot for `adapter` (the functional PJRT path:
    /// the artifacts bake one weight stack per slot, so an adapter must
    /// always land in the same slot for its outputs to be deterministic).
    /// Returns `cold = true` when the slot's occupant changes — the
    /// moment a real system would pay the host→device transfer.
    pub fn acquire_fixed(&mut self, adapter: u64) -> SlotAcquire {
        let slot = (adapter % self.slots.len() as u64) as usize;
        let cold = self.slots[slot] != Some(adapter);
        if cold {
            if let Some(old) = self.slots[slot] {
                self.index.remove(&old);
            }
            self.slots[slot] = Some(adapter);
            self.index.insert(adapter, slot);
        }
        self.touch(slot);
        SlotAcquire { slot, cold }
    }
}

/// Cold-start latency model: what loading an adapter host→device costs
/// (Fig 3-Right).
#[derive(Debug, Clone)]
pub struct LoaderModel {
    pub cfg: LlamaConfig,
    pub gpu: GpuSpec,
    /// Scale factor applied to the modeled time (lets the tiny-model
    /// functional path use proportionally tiny delays).
    pub scale: f64,
}

impl LoaderModel {
    /// Standard model.
    pub fn new(cfg: LlamaConfig, gpu: GpuSpec) -> LoaderModel {
        LoaderModel {
            cfg,
            gpu,
            scale: 1.0,
        }
    }

    /// Modeled load time for an adapter (seconds).
    pub fn load_time(&self, spec: &LoraSpec) -> f64 {
        self.gpu.h2d_time(spec.weight_bytes(&self.cfg)) * self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repository_install_get() {
        let mut repo = HostRepository::new();
        repo.install(LoraSpec::standard(1, 64, "llama2-7b"));
        repo.install(LoraSpec::standard(2, 8, "llama2-7b"));
        assert_eq!(repo.len(), 2);
        assert_eq!(repo.get(1).unwrap().rank, 64);
        assert!(repo.get(3).is_none());
    }

    #[test]
    fn slot_cache_hit_and_miss() {
        let mut c = DeviceSlotCache::new(2);
        let a = c.acquire(10);
        assert!(a.cold);
        let b = c.acquire(10);
        assert!(!b.cold);
        assert_eq!(a.slot, b.slot);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = DeviceSlotCache::new(2);
        let s1 = c.acquire(1).slot;
        let _s2 = c.acquire(2).slot;
        c.acquire(1); // 1 now MRU; 2 is LRU
        let s3 = c.acquire(3); // evicts 2
        assert!(s3.cold);
        assert!(c.resident(1));
        assert!(!c.resident(2));
        assert!(c.resident(3));
        assert_ne!(s3.slot, s1);
    }

    #[test]
    fn distinct_adapters_get_distinct_slots_until_full() {
        let mut c = DeviceSlotCache::new(4);
        let slots: Vec<usize> = (0..4).map(|i| c.acquire(i).slot).collect();
        let mut sorted = slots.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
    }

    #[test]
    fn acquire_fixed_is_deterministic_and_tracks_residency() {
        let mut c = DeviceSlotCache::new(8);
        let a = c.acquire_fixed(3);
        assert!(a.cold);
        assert_eq!(a.slot, 3);
        assert!(!c.acquire_fixed(3).cold); // warm now
        // Adapter 11 collides on slot 3 → evicts 3.
        let b = c.acquire_fixed(11);
        assert!(b.cold);
        assert_eq!(b.slot, 3);
        assert!(c.acquire_fixed(3).cold); // 3 was evicted
    }

    #[test]
    fn loader_model_scales_with_rank() {
        let m = LoaderModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10());
        let t8 = m.load_time(&LoraSpec::standard(1, 8, "llama2-7b"));
        let t64 = m.load_time(&LoraSpec::standard(2, 64, "llama2-7b"));
        assert!(t64 > t8);
        // Fig 3-Right band: tens of ms for rank 64.
        assert!((15e-3..30e-3).contains(&t64), "t64={t64}");
    }
}
