//! Profiling driver for performance-model calibration (paper §5:
//! "lightweight serving performance profiling, involving varying batch
//! sizes and heterogeneous adapters on a specific GPU").
//!
//! The profiler sweeps a (batch-size × rank-mix) grid, measures each
//! configuration with a caller-supplied measurement function (the
//! analytical GPU model in simulation; wall-clock kernels on a real
//! testbed), and fits a [`PerfModel`] per kernel.

use super::{KernelKind, PerfModel};
use crate::util::rng::Rng;

/// A profiling plan: which batch sizes and ranks to sweep.
#[derive(Debug, Clone)]
pub struct ProfilePlan {
    pub batch_sizes: Vec<usize>,
    pub ranks: Vec<usize>,
    /// Heterogeneous mixes per batch size (random rank assignments).
    pub mixes_per_size: usize,
    pub seed: u64,
}

impl Default for ProfilePlan {
    fn default() -> Self {
        ProfilePlan {
            batch_sizes: vec![1, 2, 4, 8, 16, 24, 32, 48, 64],
            ranks: vec![8, 16, 32, 64, 128],
            mixes_per_size: 6,
            seed: 0x9A9A,
        }
    }
}

impl ProfilePlan {
    /// Enumerate the batches (rank vectors) this plan profiles:
    /// homogeneous batches for every (size, rank) plus random
    /// heterogeneous mixes.
    pub fn batches(&self) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::new();
        for &b in &self.batch_sizes {
            for &r in &self.ranks {
                out.push(vec![r; b]);
            }
            for _ in 0..self.mixes_per_size {
                let mix: Vec<usize> =
                    (0..b).map(|_| *rng.choose(&self.ranks)).collect();
                out.push(mix);
            }
        }
        out
    }
}

/// Run the plan against `measure` and fit a model for `kernel`.
/// `measure(ranks)` must return the observed iteration latency (seconds).
pub fn calibrate(
    kernel: KernelKind,
    plan: &ProfilePlan,
    mut measure: impl FnMut(&[usize]) -> f64,
) -> Option<PerfModel> {
    let points: Vec<(Vec<usize>, f64)> = plan
        .batches()
        .into_iter()
        .map(|ranks| {
            let y = measure(&ranks);
            (ranks, y)
        })
        .collect();
    PerfModel::fit(kernel, &points)
}

/// Calibrate both kernels at once against per-kernel measurement closures.
pub fn calibrate_both(
    plan: &ProfilePlan,
    mut measure_bgmv: impl FnMut(&[usize]) -> f64,
    mut measure_mbgmv: impl FnMut(&[usize]) -> f64,
) -> Option<(PerfModel, PerfModel)> {
    let b = calibrate(KernelKind::Bgmv, plan, &mut measure_bgmv)?;
    let m = calibrate(KernelKind::Mbgmv, plan, &mut measure_mbgmv)?;
    Some((b, m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_includes_homogeneous_and_mixed() {
        let plan = ProfilePlan::default();
        let batches = plan.batches();
        let homo = batches
            .iter()
            .filter(|b| b.windows(2).all(|w| w[0] == w[1]))
            .count();
        assert!(homo >= plan.batch_sizes.len() * plan.ranks.len());
        assert!(batches.len() > homo, "need heterogeneous mixes too");
    }

    #[test]
    fn calibrate_recovers_noisy_linear_ground_truth() {
        let plan = ProfilePlan::default();
        let mut rng = Rng::new(3);
        let model = calibrate(KernelKind::Mbgmv, &plan, |ranks| {
            let f = KernelKind::Mbgmv.feature(ranks);
            7e-6 * f + 28e-3 + rng.normal_with(0.0, 2e-4)
        })
        .unwrap();
        assert!((model.alpha - 7e-6).abs() < 5e-7, "alpha={}", model.alpha);
        assert!((model.beta - 28e-3).abs() < 5e-4, "beta={}", model.beta);
        // The paper reports R² = 0.96; with small noise we should beat it.
        assert!(model.r2 > 0.96, "r2={}", model.r2);
    }

    #[test]
    fn calibrate_both_returns_two_models() {
        let plan = ProfilePlan {
            mixes_per_size: 2,
            ..Default::default()
        };
        let (b, m) = calibrate_both(
            &plan,
            |r| 1e-5 * KernelKind::Bgmv.feature(r) + 0.03,
            |r| 2e-5 * KernelKind::Mbgmv.feature(r) + 0.03,
        )
        .unwrap();
        assert_eq!(b.kernel, KernelKind::Bgmv);
        assert_eq!(m.kernel, KernelKind::Mbgmv);
        assert!(b.r2 > 0.999 && m.r2 > 0.999);
    }
}
