//! Heterogeneity-aware performance models (paper §5, Fig 9).
//!
//! The paper fits linear models from serving profiles:
//!
//! ```text
//! Perf_BGMV(S)  = α_B · |S| · max_{i∈S} rank(i) + β_B
//! Perf_MBGMV(S) = α_M · Σ_{i∈S} rank(i)         + β_M
//! ```
//!
//! Both kernels are memory-bandwidth bound (>70% membw in the paper's
//! Nsight characterization), which is where the linearity comes from:
//! BGMV streams `|S| · max_rank` padded adapter rows, MBGMV streams
//! exactly `Σ rank` rows. [`PerfModel::fit`] recovers (α, β) from
//! profiled points via OLS and reports R² (the paper gets 0.96).

pub mod profiler;

use crate::util::stats::{ols, LinearFit};

/// Which GPU LoRA kernel a server uses (determines the cost feature).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Punica-style padded kernel: feature = |S| · max_rank.
    Bgmv,
    /// S-LoRA-style padding-free kernel: feature = Σ rank.
    Mbgmv,
}

impl KernelKind {
    /// Parse from the config string.
    pub fn parse(s: &str) -> Option<KernelKind> {
        match s.to_ascii_lowercase().as_str() {
            "bgmv" => Some(KernelKind::Bgmv),
            "mbgmv" => Some(KernelKind::Mbgmv),
            _ => None,
        }
    }

    /// The scalar feature this kernel's latency is linear in.
    pub fn feature(&self, ranks: &[usize]) -> f64 {
        self.feature_iter(ranks.iter().copied())
    }

    /// Feature over an iterator of ranks — lets the scheduler compose
    /// running ∥ queued ∥ candidate without concatenating vectors (the
    /// allocation-free hot path of Algorithm 1; see EXPERIMENTS.md §Perf).
    pub fn feature_iter(&self, ranks: impl Iterator<Item = usize>) -> f64 {
        match self {
            KernelKind::Bgmv => {
                let (mut n, mut max) = (0usize, 0usize);
                for r in ranks {
                    n += 1;
                    max = max.max(r);
                }
                (n * max) as f64
            }
            KernelKind::Mbgmv => ranks.sum::<usize>() as f64,
        }
    }
}

/// A fitted linear latency model `latency = α · feature + β` for one
/// (kernel, phase) pair.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub kernel: KernelKind,
    /// Slope α (seconds per feature unit).
    pub alpha: f64,
    /// Intercept β (seconds) — the batch-independent base-model cost.
    pub beta: f64,
    /// Fit quality on the training profile.
    pub r2: f64,
}

impl PerfModel {
    /// Construct directly from known coefficients.
    pub fn from_coefficients(kernel: KernelKind, alpha: f64, beta: f64) -> PerfModel {
        PerfModel {
            kernel,
            alpha,
            beta,
            r2: 1.0,
        }
    }

    /// Fit from profiled `(ranks-in-batch, measured latency)` points.
    pub fn fit(kernel: KernelKind, points: &[(Vec<usize>, f64)]) -> Option<PerfModel> {
        let xs: Vec<Vec<f64>> = points
            .iter()
            .map(|(ranks, _)| vec![kernel.feature(ranks)])
            .collect();
        let ys: Vec<f64> = points.iter().map(|&(_, y)| y).collect();
        let LinearFit { coef, intercept, r2 } = ols(&xs, &ys)?;
        Some(PerfModel {
            kernel,
            alpha: coef[0],
            beta: intercept,
            r2,
        })
    }

    /// Predicted iteration latency (seconds) for a batch with the given
    /// ranks — the linear extension `α·feature + β` for *all* batch
    /// sizes, including the empty batch (→ β).
    ///
    /// Returning 0 for the empty batch would make Algorithm 1's marginal
    /// cost `Δ = predict(S+r) − predict(S)` jump by β when a server is
    /// idle, so the scheduler would avoid empty servers and herd
    /// requests onto loaded ones (observed as an attainment collapse at
    /// 60-instance scale before this was fixed).
    pub fn predict(&self, ranks: &[usize]) -> f64 {
        self.alpha * self.kernel.feature(ranks) + self.beta
    }

    /// Allocation-free prediction over an iterator of ranks.
    pub fn predict_iter(&self, ranks: impl Iterator<Item = usize>) -> f64 {
        self.alpha * self.kernel.feature_iter(ranks) + self.beta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn features_match_paper_definitions() {
        let ranks = vec![8, 64, 32];
        assert_eq!(KernelKind::Bgmv.feature(&ranks), (3 * 64) as f64);
        assert_eq!(KernelKind::Mbgmv.feature(&ranks), 104.0);
        assert_eq!(KernelKind::Bgmv.feature(&[]), 0.0);
    }

    #[test]
    fn fit_recovers_synthetic_linear_model() {
        // Ground truth: latency = 5e-6 · feature + 30e-3.
        let mut points = Vec::new();
        for batch in 1..=32usize {
            for &rank in &[8usize, 16, 32, 64] {
                let ranks = vec![rank; batch];
                let f = KernelKind::Bgmv.feature(&ranks);
                points.push((ranks, 5e-6 * f + 30e-3));
            }
        }
        let m = PerfModel::fit(KernelKind::Bgmv, &points).unwrap();
        assert!((m.alpha - 5e-6).abs() < 1e-9);
        assert!((m.beta - 30e-3).abs() < 1e-7);
        assert!(m.r2 > 0.9999);
    }

    #[test]
    fn bgmv_sensitive_to_max_mbgmv_to_sum() {
        let b = PerfModel::from_coefficients(KernelKind::Bgmv, 1e-5, 0.0);
        let m = PerfModel::from_coefficients(KernelKind::Mbgmv, 1e-5, 0.0);
        // Adding one rank-64 request to 24 rank-32 requests:
        let before: Vec<usize> = vec![32; 24];
        let mut after = before.clone();
        after.push(64);
        // BGMV jumps: max rank doubles for the whole batch.
        let bgmv_jump = b.predict(&after) / b.predict(&before);
        assert!(bgmv_jump > 2.0, "bgmv jump {bgmv_jump}");
        // MBGMV grows only by the added rank.
        let mbgmv_jump = m.predict(&after) / m.predict(&before);
        assert!(mbgmv_jump < 1.1, "mbgmv jump {mbgmv_jump}");
    }

    #[test]
    fn paper_toy_example_fig5() {
        // Fig 5: Instance1 = 24×rank-32, Instance2 = 16×rank-64, SLO 36ms.
        // BGMV: 34.8ms and 35.8ms; MBGMV: 35.3ms and 35.9ms.
        // Calibrate coefficients to land near those numbers.
        let b = PerfModel::from_coefficients(KernelKind::Bgmv, 1.3e-5, 24.8e-3);
        let i1: Vec<usize> = vec![32; 24];
        let i2: Vec<usize> = vec![64; 16];
        let l1 = b.predict(&i1);
        let l2 = b.predict(&i2);
        assert!((l1 - 34.8e-3).abs() < 1e-3, "{l1}");
        assert!((l2 - 38.1e-3).abs() < 3e-3, "{l2}");
        // New rank-64 request: to I1 raises max rank to 64 → violates 36ms.
        let mut i1_new = i1.clone();
        i1_new.push(64);
        assert!(b.predict(&i1_new) > 36e-3);
    }

    #[test]
    fn empty_batch_predicts_intercept() {
        // Linear extension: predict(∅) = β, so Algorithm 1's marginal
        // cost has no cliff at idle servers.
        let m = PerfModel::from_coefficients(KernelKind::Mbgmv, 1e-5, 30e-3);
        assert_eq!(m.predict(&[]), 30e-3);
        let marginal_idle = m.predict(&[8]) - m.predict(&[]);
        let marginal_busy = m.predict(&[8, 8]) - m.predict(&[8]);
        assert!((marginal_idle - marginal_busy).abs() < 1e-12);
    }

    #[test]
    fn parse_kernel_kind() {
        assert_eq!(KernelKind::parse("BGMV"), Some(KernelKind::Bgmv));
        assert_eq!(KernelKind::parse("mbgmv"), Some(KernelKind::Mbgmv));
        assert_eq!(KernelKind::parse("cutlass"), None);
    }
}
