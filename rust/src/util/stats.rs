//! Descriptive statistics, empirical CDFs, and ordinary least squares.
//!
//! Used by the metrics layer (TTFT / time-per-token / request-latency
//! percentiles, CDF tables for the paper's figures) and by the
//! performance-model fitter (§5 of the paper: linear models with R²).

/// Summary of a sample: count, mean, std, min/max, percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / count as f64;
        Some(Summary {
            count,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: sorted[count - 1],
        })
    }
}

/// One summary statistic formatted in milliseconds with one decimal
/// ("-" when the sample was empty) — the cell format shared by the
/// `cluster`/`coordinator` CLI tables and the cluster/placement
/// benches, so their report columns cannot drift apart.
pub fn ms_or_dash(s: &Option<Summary>, f: fn(&Summary) -> f64) -> String {
    s.as_ref()
        .map_or("-".to_string(), |s| format!("{:.1}", f(s) * 1e3))
}

/// Percentile (0..=100) of an already-sorted sample, with linear
/// interpolation between closest ranks.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile of an unsorted sample (copies + sorts).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// An empirical CDF: sorted values + the fraction ≤ each value.
/// `points(n)` returns `n` evenly spaced (value, cum_fraction) pairs for
/// plotting the paper's CDF figures (Figs 10, 13, 15, 19, 20).
#[derive(Debug, Clone)]
pub struct Ecdf {
    sorted: Vec<f64>,
}

impl Ecdf {
    /// Build from a sample (empty samples allowed; `points` then empty).
    pub fn new(xs: &[f64]) -> Self {
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { sorted }
    }

    /// Fraction of the sample ≤ `x`.
    pub fn eval(&self, x: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        self.sorted.partition_point(|&v| v <= x) as f64 / self.sorted.len() as f64
    }

    /// `n` (value, fraction) pairs at evenly spaced quantiles.
    pub fn points(&self, n: usize) -> Vec<(f64, f64)> {
        if self.sorted.is_empty() || n == 0 {
            return Vec::new();
        }
        (0..n)
            .map(|i| {
                let q = (i as f64 + 1.0) / n as f64;
                let v = percentile_sorted(&self.sorted, q * 100.0);
                (v, q)
            })
            .collect()
    }

    /// Number of underlying samples.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// True if no samples.
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }
}

/// A fixed-width histogram over `[lo, hi)` with `bins` buckets
/// (under/overflow clamped into the edge buckets).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// New histogram covering `[lo, hi)` with `bins` buckets.
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self {
            lo,
            hi,
            counts: vec![0; bins],
            total: 0,
        }
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        let bins = self.counts.len();
        let idx = if x < self.lo {
            0
        } else if x >= self.hi {
            bins - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * bins as f64) as usize
        };
        self.counts[idx.min(bins - 1)] += 1;
        self.total += 1;
    }

    /// (bucket_midpoint, fraction) rows.
    pub fn normalized(&self) -> Vec<(f64, f64)> {
        let width = (self.hi - self.lo) / self.counts.len() as f64;
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let mid = self.lo + width * (i as f64 + 0.5);
                let frac = if self.total == 0 {
                    0.0
                } else {
                    c as f64 / self.total as f64
                };
                (mid, frac)
            })
            .collect()
    }

    /// Total number of recorded observations.
    pub fn total(&self) -> u64 {
        self.total
    }
}

/// Result of a simple (possibly multivariate) least-squares fit.
#[derive(Debug, Clone)]
pub struct LinearFit {
    /// Coefficients for each feature column.
    pub coef: Vec<f64>,
    /// Intercept term.
    pub intercept: f64,
    /// Coefficient of determination on the training data.
    pub r2: f64,
}

impl LinearFit {
    /// Predict for one feature row.
    pub fn predict(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.coef.len());
        self.intercept + x.iter().zip(&self.coef).map(|(a, b)| a * b).sum::<f64>()
    }
}

/// Ordinary least squares for `y ≈ intercept + coef·x`.
///
/// `xs` is row-major: one feature row per observation. Solves the normal
/// equations by Gaussian elimination with partial pivoting — the perf
/// models here have 1–2 features, so numerics are not a concern.
pub fn ols(xs: &[Vec<f64>], ys: &[f64]) -> Option<LinearFit> {
    let n = xs.len();
    if n == 0 || n != ys.len() {
        return None;
    }
    let k = xs[0].len();
    if xs.iter().any(|r| r.len() != k) {
        return None;
    }
    let dim = k + 1; // features + intercept
    if n < dim {
        return None;
    }

    // Build X^T X and X^T y with an implicit leading 1s column.
    let mut a = vec![vec![0.0f64; dim]; dim];
    let mut b = vec![0.0f64; dim];
    for (row, &y) in xs.iter().zip(ys) {
        let mut ext = Vec::with_capacity(dim);
        ext.push(1.0);
        ext.extend_from_slice(row);
        for i in 0..dim {
            b[i] += ext[i] * y;
            for j in 0..dim {
                a[i][j] += ext[i] * ext[j];
            }
        }
    }

    let sol = solve(&mut a, &mut b)?;
    let intercept = sol[0];
    let coef = sol[1..].to_vec();

    // R² on the training data.
    let y_mean = mean(ys);
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    for (row, &y) in xs.iter().zip(ys) {
        let pred =
            intercept + row.iter().zip(&coef).map(|(a, b)| a * b).sum::<f64>();
        ss_res += (y - pred) * (y - pred);
        ss_tot += (y - y_mean) * (y - y_mean);
    }
    let r2 = if ss_tot == 0.0 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    };

    Some(LinearFit {
        coef,
        intercept,
        r2,
    })
}

/// Gaussian elimination with partial pivoting; consumes its inputs.
fn solve(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // Pivot.
        let mut pivot = col;
        for row in col + 1..n {
            if a[row][col].abs() > a[pivot][col].abs() {
                pivot = row;
            }
        }
        if a[pivot][col].abs() < 1e-12 {
            return None; // singular
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        // Eliminate below.
        for row in col + 1..n {
            let f = a[row][col] / a[col][col];
            for j in col..n {
                a[row][j] -= f * a[col][j];
            }
            b[row] -= f * b[col];
        }
    }
    // Back-substitute.
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for j in col + 1..n {
            acc -= a[col][j] * x[j];
        }
        x[col] = acc / a[col][col];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&xs, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_eval_and_points() {
        let e = Ecdf::new(&[1.0, 2.0, 3.0, 4.0]);
        assert!((e.eval(0.5) - 0.0).abs() < 1e-12);
        assert!((e.eval(2.0) - 0.5).abs() < 1e-12);
        assert!((e.eval(10.0) - 1.0).abs() < 1e-12);
        let pts = e.points(4);
        assert_eq!(pts.len(), 4);
        assert!((pts[3].1 - 1.0).abs() < 1e-12);
        assert!((pts[3].0 - 4.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.record(-5.0); // clamps to bucket 0
        h.record(0.5);
        h.record(9.5);
        h.record(50.0); // clamps to last bucket
        assert_eq!(h.total(), 4);
        let rows = h.normalized();
        assert!((rows[0].1 - 0.5).abs() < 1e-12);
        assert!((rows[9].1 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ols_recovers_exact_line() {
        // y = 3 + 2a - b
        let xs: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![i as f64, (i * i % 7) as f64])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|r| 3.0 + 2.0 * r[0] - r[1]).collect();
        let fit = ols(&xs, &ys).unwrap();
        assert!((fit.intercept - 3.0).abs() < 1e-8);
        assert!((fit.coef[0] - 2.0).abs() < 1e-8);
        assert!((fit.coef[1] + 1.0).abs() < 1e-8);
        assert!(fit.r2 > 0.999999);
        assert!((fit.predict(&[5.0, 1.0]) - 12.0).abs() < 1e-8);
    }

    #[test]
    fn ols_with_noise_high_r2() {
        let mut rng = crate::util::rng::Rng::new(5);
        let xs: Vec<Vec<f64>> = (0..200).map(|_| vec![rng.uniform(0.0, 100.0)]).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|r| 1.5 * r[0] + 4.0 + rng.normal_with(0.0, 1.0))
            .collect();
        let fit = ols(&xs, &ys).unwrap();
        assert!((fit.coef[0] - 1.5).abs() < 0.05, "coef={:?}", fit.coef);
        assert!(fit.r2 > 0.99, "r2={}", fit.r2);
    }

    #[test]
    fn ols_degenerate_cases() {
        assert!(ols(&[], &[]).is_none());
        // Singular: identical feature rows.
        let xs = vec![vec![1.0], vec![1.0], vec![1.0]];
        let ys = vec![1.0, 2.0, 3.0];
        assert!(ols(&xs, &ys).is_none());
    }
}
