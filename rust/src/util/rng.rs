//! Deterministic PRNG and distribution samplers.
//!
//! The offline vendor set has no `rand`/`rand_distr`, so this module
//! provides the generators the workload layer needs: a SplitMix64 seeder,
//! Xoshiro256** as the core generator, and exponential / Poisson / Zipf /
//! log-normal / gamma samplers used by the synthetic and MAF-like traces.
//!
//! All samplers are deterministic given a seed so every experiment in
//! EXPERIMENTS.md is exactly reproducible.

/// SplitMix64 — used to expand a single `u64` seed into the Xoshiro state.
/// Reference: Steele, Lea & Flood, "Fast Splittable Pseudorandom Number
/// Generators" (OOPSLA 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the crate-wide PRNG (same algorithm as `rand_xoshiro`).
/// Period 2^256−1, passes BigCrush; plenty for workload generation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (recommended by the xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with success probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean `1/lambda`). Inter-arrival
    /// times of a Poisson process.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        // Avoid ln(0): f64() is in [0,1), so 1-f64() is in (0,1].
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Standard normal via Box–Muller (polar rejection-free form).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64(); // (0, 1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean `mu` and std `sigma`.
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal: `exp(N(mu, sigma))`. Used for Alpaca-like length
    /// distributions (heavy right tail).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Poisson-distributed count with mean `lambda`.
    ///
    /// Knuth's product method for small lambda; for `lambda > 30` uses the
    /// normal approximation with continuity correction (adequate for
    /// arrival batching in the simulator).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let x = self.normal_with(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Sample an index from an explicit discrete probability mass function
    /// (weights need not be normalized).
    pub fn discrete(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "discrete() with zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

/// Zipf-distributed sampler over ranks `1..=n` with exponent `s`.
///
/// Precomputes the CDF once (O(n) memory) so each sample is a binary
/// search — the MAF popularity generator draws millions of samples.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler for `n` items with skew exponent `s` (s≈1 typical).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    /// Probability mass of rank `k` (0-based index).
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    /// Number of items.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// True if the support is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw a 0-based rank.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // partition_point: first index with cdf[i] >= u.
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// A Poisson-process event-time iterator: successive arrival timestamps
/// (seconds) with rate `rps`, starting at `t0`.
#[derive(Debug, Clone)]
pub struct PoissonProcess {
    rng: Rng,
    rate: f64,
    t: f64,
}

impl PoissonProcess {
    /// New process with `rate` events/second starting at time `t0`.
    pub fn new(seed: u64, rate: f64, t0: f64) -> Self {
        Self {
            rng: Rng::new(seed),
            rate,
            t: t0,
        }
    }

    /// Current rate (events/s).
    pub fn rate(&self) -> f64 {
        self.rate
    }
}

impl Iterator for PoissonProcess {
    type Item = f64;
    fn next(&mut self) -> Option<f64> {
        self.t += self.rng.exp(self.rate);
        Some(self.t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = rng.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_close() {
        let mut rng = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_close_small_and_large_lambda() {
        let mut rng = Rng::new(13);
        for &lam in &[0.5, 3.0, 9.0, 50.0] {
            let n = 50_000;
            let mean: f64 =
                (0..n).map(|_| rng.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lam).abs() < lam.max(1.0) * 0.05,
                "lambda={lam} mean={mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_normalized() {
        let z = Zipf::new(100, 1.0);
        let total: f64 = (0..100).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(z.pmf(0) > z.pmf(1));
        assert!(z.pmf(1) > z.pmf(50));
        // Head heavier than tail: top-10 should hold most of the mass at s=1.
        let head: f64 = (0..10).map(|k| z.pmf(k)).sum();
        assert!(head > 0.5, "head={head}");
    }

    #[test]
    fn zipf_sampling_matches_pmf() {
        let z = Zipf::new(20, 1.1);
        let mut rng = Rng::new(23);
        let n = 200_000;
        let mut counts = vec![0u64; 20];
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        for k in 0..20 {
            let emp = counts[k] as f64 / n as f64;
            assert!(
                (emp - z.pmf(k)).abs() < 0.01,
                "k={k} emp={emp} pmf={}",
                z.pmf(k)
            );
        }
    }

    #[test]
    fn poisson_process_is_increasing_with_right_rate() {
        let mut p = PoissonProcess::new(31, 10.0, 0.0);
        let mut last = 0.0;
        let mut count = 0;
        loop {
            let t = p.next().unwrap();
            assert!(t > last);
            last = t;
            count += 1;
            if t > 100.0 {
                break;
            }
        }
        // ~1000 events in 100s at 10 rps.
        assert!((800..1200).contains(&count), "count={count}");
    }

    #[test]
    fn discrete_respects_weights() {
        let mut rng = Rng::new(37);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u64; 3];
        for _ in 0..40_000 {
            counts[rng.discrete(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(41);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn lognormal_positive() {
        let mut rng = Rng::new(43);
        for _ in 0..1000 {
            assert!(rng.lognormal(3.0, 1.0) > 0.0);
        }
    }
}
