//! Tiny command-line argument parser (no `clap` in the offline vendor
//! set). Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments: flags, key-value options, and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: Vec<String>,
    opts: BTreeMap<String, String>,
    positional: Vec<String>,
}

/// CLI parse error.
#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    Invalid {
        key: String,
        value: String,
        reason: String,
    },
    Unknown(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(k) => write!(f, "missing value for --{k}"),
            CliError::Invalid { key, value, reason } => {
                write!(f, "invalid value for --{key}: {value} ({reason})")
            }
            CliError::Unknown(k) => write!(f, "unknown option --{k}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `value_opts` lists option names that take a value; anything else
    /// starting with `--` is treated as a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        value_opts: &[&str],
    ) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&body) {
                    match iter.next() {
                        Some(v) => {
                            out.opts.insert(body.to_string(), v);
                        }
                        None => return Err(CliError::MissingValue(body.into())),
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse directly from `std::env::args()` (skipping argv[0]).
    pub fn from_env(value_opts: &[&str]) -> Result<Args, CliError> {
        Self::parse(std::env::args().skip(1), value_opts)
    }

    /// Is a boolean flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw string option.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    /// Typed option (usize / f64 / u64 ...).
    pub fn opt_parse<T: std::str::FromStr>(
        &self,
        name: &str,
    ) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|e| CliError::Invalid {
                key: name.into(),
                value: v.into(),
                reason: e.to_string(),
            }),
        }
    }

    /// Typed option with default.
    pub fn opt_parse_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional (typically a subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], value_opts: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), value_opts).unwrap()
    }

    #[test]
    fn flags_opts_positionals() {
        let a = parse(
            &["serve", "--port", "8080", "--verbose", "--name=demo", "extra"],
            &["port"],
        );
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.opt("port"), Some("8080"));
        assert_eq!(a.opt("name"), Some("demo"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["serve".to_string(), "extra".to_string()]);
    }

    #[test]
    fn typed_parse() {
        let a = parse(&["--rps=9.5", "--n", "100"], &["n"]);
        assert_eq!(a.opt_parse::<f64>("rps").unwrap(), Some(9.5));
        assert_eq!(a.opt_parse_or::<usize>("n", 0).unwrap(), 100);
        assert_eq!(a.opt_parse_or::<usize>("missing", 7).unwrap(), 7);
    }

    #[test]
    fn errors() {
        let e = Args::parse(["--port".to_string()].into_iter(), &["port"]);
        assert!(e.is_err());
        let a = parse(&["--n=abc"], &[]);
        assert!(a.opt_parse::<usize>("n").is_err());
    }
}
