//! Foundation utilities built in-repo because the offline vendor set has
//! no `rand`, `serde`, `clap`, or `statrs`: PRNGs and distribution
//! samplers ([`rng`]), descriptive statistics and least-squares fitting
//! ([`stats`]), a minimal JSON codec ([`json`]), and a tiny CLI argument
//! parser ([`cli`]).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
