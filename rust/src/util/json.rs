//! A minimal JSON codec (no `serde` in the offline vendor set).
//!
//! Supports the full JSON grammar needed by the artifact manifests
//! (`artifacts/manifest.json` written by `python/compile/aot.py`), config
//! files, and the machine-readable bench outputs. Numbers are `f64`;
//! object key order is preserved (Vec of pairs) so emitted files diff
//! cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Parse or access error.
#[derive(Debug)]
pub enum JsonError {
    Parse(usize, String),
    MissingKey(String),
    Type(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Parse(at, what) => write!(f, "parse error at byte {at}: {what}"),
            JsonError::MissingKey(k) => write!(f, "missing key: {k}"),
            JsonError::Type(k) => write!(f, "type mismatch for {k}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let bytes = s.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(JsonError::Parse(p.pos, "trailing data".into()));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field access that errors on absence.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError::MissingKey(key.into()))
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integral number).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As object pairs.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Convert an object to a BTreeMap view (copies keys).
    pub fn to_map(&self) -> Option<BTreeMap<String, Json>> {
        self.as_obj()
            .map(|pairs| pairs.iter().cloned().collect())
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Build a `Json::Obj` from pairs, ergonomically.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build a `Json::Num`.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Build a `Json::Str`.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Build a `Json::Arr` from f64s.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..width * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl fmt::Display) -> Result<T, JsonError> {
        Err(JsonError::Parse(self.pos, msg.to_string()))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| {
                                JsonError::Parse(self.pos, "bad \\u".into())
                            })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| {
                                JsonError::Parse(self.pos, "bad \\u".into())
                            })?;
                            // BMP only; surrogate pairs unsupported (not
                            // needed for manifests).
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| JsonError::Parse(self.pos, "bad utf8".into()))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError::Parse(start, e.to_string()))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let src = r#"{"name":"bgmv","ranks":[8,16,64],"pi":3.25,"ok":true,"none":null,"nested":{"a":[{"b":1}]}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"a": 3, "b": "x", "c": [1,2], "d": false}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("d").unwrap().as_bool(), Some(false));
        assert!(v.get("zzz").is_none());
        assert!(v.req("zzz").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn numbers() {
        for (src, want) in [
            ("0", 0.0),
            ("-12", -12.0),
            ("3.5", 3.5),
            ("1e3", 1000.0),
            ("-2.5E-2", -0.025),
        ] {
            assert_eq!(Json::parse(src).unwrap().as_f64(), Some(want), "{src}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "{\"a\" 1}"] {
            assert!(Json::parse(src).is_err(), "{src:?} should fail");
        }
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(2.5).to_string_compact(), "2.5");
    }

    #[test]
    fn builder_helpers() {
        let v = obj(vec![("xs", arr_f64(&[1.0, 2.0])), ("label", s("hi"))]);
        assert_eq!(v.get("label").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("xs").unwrap().as_arr().unwrap().len(), 2);
    }
}
