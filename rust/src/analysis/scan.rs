//! Line-oriented Rust source masking for the repo lints.
//!
//! [`mask_lines`] splits a source file into per-line `(code, comment)`
//! pairs: `code` is the line with string/char-literal *contents* and
//! all comments removed (delimiters kept, so token shapes survive), and
//! `comment` is the concatenated comment text that appears on the line.
//! Rules then scan `code` without tripping over `"unsafe"` inside a
//! string or `Ordering::Relaxed` inside a doc comment, and look for
//! their `// SAFETY:` / `// ORDERING:` tags in `comment`.
//!
//! The masker is a character-level state machine covering the token
//! forms that actually occur in this tree: line comments, nested block
//! comments, string literals (including `\"`-escapes and backslash
//! line continuations), raw strings `r"…"` / `r#"…"#`, and char
//! literals vs. lifetimes. It is deliberately *not* a full lexer —
//! byte/ C-string literal prefixes and exotic raw-identifier cases fall
//! through harmlessly as code.

/// One masked source line: code with literals/comments blanked, plus
/// the comment text found on the line.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MaskedLine {
    /// Source code with string/char contents and comments removed.
    pub code: String,
    /// Concatenated comment text on this line (line + block comments).
    pub comment: String,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Mode {
    Code,
    LineComment,
    BlockComment,
    Str,
    RawStr,
}

/// Mask `src` into per-line code/comment pairs. Always returns at
/// least one line (an empty file yields one empty line), and returns
/// exactly `src.lines().count().max(1)` entries for newline-terminated
/// input plus the trailing fragment.
pub fn mask_lines(src: &str) -> Vec<MaskedLine> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Vec::new();
    let mut line = MaskedLine::default();
    let mut mode = Mode::Code;
    let mut block_depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            out.push(std::mem::take(&mut line));
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                if c == '/' && i + 1 < n && cs[i + 1] == '/' {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && i + 1 < n && cs[i + 1] == '*' {
                    mode = Mode::BlockComment;
                    block_depth = 1;
                    i += 2;
                } else if c == '"' {
                    line.code.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if c == 'r' && i + 1 < n && (cs[i + 1] == '"' || cs[i + 1] == '#') {
                    // Candidate raw string: r"…" or r#"…"# (any hash count).
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && cs[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && cs[j] == '"' {
                        raw_hashes = h;
                        line.code.push_str("r\"");
                        mode = Mode::RawStr;
                        i = j + 1;
                    } else {
                        line.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs. lifetime.
                    if i + 1 < n && cs[i + 1] == '\\' {
                        // Escaped char literal: scan to the closing quote.
                        let mut j = i + 2;
                        while j < n && cs[j] != '\'' {
                            j += 1;
                        }
                        line.code.push_str("' '");
                        i = j + 1;
                    } else if i + 2 < n && cs[i + 2] == '\'' {
                        line.code.push_str("' '");
                        i += 3;
                    } else {
                        // Lifetime (or dangling quote): keep as code.
                        line.code.push(c);
                        i += 1;
                    }
                } else {
                    line.code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                line.comment.push(c);
                i += 1;
            }
            Mode::BlockComment => {
                if c == '/' && i + 1 < n && cs[i + 1] == '*' {
                    block_depth += 1;
                    line.comment.push(' ');
                    i += 2;
                } else if c == '*' && i + 1 < n && cs[i + 1] == '/' {
                    block_depth -= 1;
                    if block_depth == 0 {
                        mode = Mode::Code;
                    }
                    i += 2;
                } else {
                    line.comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    if i + 1 < n && cs[i + 1] == '\n' {
                        // Backslash line continuation: leave the newline
                        // for the top-of-loop handler so line numbers
                        // stay in sync.
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    line.code.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && cs[j] == '#' && h < raw_hashes {
                        h += 1;
                        j += 1;
                    }
                    if h == raw_hashes {
                        line.code.push('"');
                        mode = Mode::Code;
                        i = j;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    out.push(line);
    out
}

/// True if `code` contains `word` as a whole identifier (not as a
/// substring of a longer identifier).
pub fn contains_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let pre_ok = start == 0 || !is_ident_byte(bytes[start - 1]);
        let post_ok = end == bytes.len() || !is_ident_byte(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// `code` with all ASCII whitespace removed — used by token-adjacency
/// checks like the `.unwrap()` scanner.
pub fn strip_ws(code: &str) -> String {
    code.chars().filter(|c| !c.is_ascii_whitespace()).collect()
}

/// Root segments of any `root::…` paths in masked `code` whose root is
/// a snake-case identifier at a path start (not preceded by an ident
/// char or `::`, not a turbofish `ident::<…>`). These are the
/// candidates for the undeclared-crate rule.
pub fn path_roots(code: &str) -> Vec<String> {
    let cs: Vec<char> = code.chars().collect();
    let n = cs.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        if !(c.is_ascii_lowercase() || c == '_') {
            i += 1;
            continue;
        }
        let boundary = i == 0 || {
            let p = cs[i - 1];
            !(p.is_ascii_alphanumeric() || p == '_' || p == ':')
        };
        let start = i;
        while i < n && (cs[i].is_ascii_lowercase() || cs[i].is_ascii_digit() || cs[i] == '_') {
            i += 1;
        }
        // A snake-case prefix of a mixed-case identifier (e.g. `aB`)
        // is not a path root; skip the whole identifier chunk.
        let clean_end = i >= n || !(cs[i].is_ascii_alphanumeric() || cs[i] == '_');
        if !boundary || !clean_end {
            while i < n && (cs[i].is_ascii_alphanumeric() || cs[i] == '_') {
                i += 1;
            }
            continue;
        }
        let mut j = i;
        while j < n && cs[j].is_ascii_whitespace() {
            j += 1;
        }
        if j + 1 < n && cs[j] == ':' && cs[j + 1] == ':' {
            j += 2;
            while j < n && cs[j].is_ascii_whitespace() {
                j += 1;
            }
            // `ident::<T>` is a turbofish on a local binding, not a path.
            if j < n && cs[j] == '<' {
                continue;
            }
            out.push(cs[start..i].iter().collect());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let src = "let x = \"unsafe // not code\"; // SAFETY: tag\nlet y = 2;\n";
        let lines = mask_lines(src);
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].code, "let x = \"\"; ");
        assert!(lines[0].comment.contains("SAFETY: tag"));
        assert_eq!(lines[1].code, "let y = 2;");
        assert!(!contains_word(&lines[0].code, "unsafe"));
    }

    #[test]
    fn nested_block_comments_and_line_sync() {
        let src = "a /* one /* two */ still */ b\nc\n";
        let lines = mask_lines(src);
        assert_eq!(lines[0].code, "a  b");
        assert!(lines[0].comment.contains("one"));
        assert_eq!(lines[1].code, "c");
    }

    #[test]
    fn raw_strings_and_char_literals() {
        let src = "let r = r#\"has \"quotes\" and // slashes\"#;\nlet c = '\\n'; let l: &'static str = \"\";\n";
        let lines = mask_lines(src);
        assert_eq!(lines[0].code, "let r = r\"\";");
        assert!(lines[1].code.contains("' '"));
        assert!(lines[1].code.contains("&'static"));
    }

    #[test]
    fn backslash_continuation_keeps_line_numbers() {
        let src = "const U: &str = \"a\\\nb\\\nc\";\nafter();\n";
        let lines = mask_lines(src);
        // 3 string lines + the `after()` line + trailing empty.
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].code, "after();");
    }

    #[test]
    fn word_boundaries() {
        assert!(contains_word("unsafe {", "unsafe"));
        assert!(!contains_word("unsafely(", "unsafe"));
        assert!(!contains_word("an_unsafe_thing", "unsafe"));
    }

    #[test]
    fn path_root_extraction() {
        assert_eq!(path_roots("libc::mmap(std::ptr::null())"), vec!["libc", "std"]);
        // Turbofish and mid-path segments are not roots.
        assert!(path_roots("x.parse::<f64>()").is_empty());
        assert!(path_roots("iter.sum::<f64>()").is_empty());
        assert_eq!(path_roots("a::b::c"), vec!["a"]);
        // Mixed-case identifiers are not snake-case roots.
        assert!(path_roots("theType::new()").is_empty());
    }
}
