//! Static analysis for the repo itself: `caraserve lint`.
//!
//! CaraServe's correctness rests on a handful of delicate concurrent
//! protocols — the §4.2 shm slots and futex doorbells, the CPU→GPU
//! handoff, the request lifecycle. This module is the standing gate
//! that keeps their invariants *visible in the source*: every `unsafe`
//! carries a `// SAFETY:` argument, every `Ordering::Relaxed` a
//! `// ORDERING:` justification, hot paths stay panic-free, decode
//! paths stay sleep-free, and every extern path root resolves to a
//! declared crate (catching a missing manifest entry without running
//! cargo — the exact failure the vendored-offline build can't afford).
//!
//! Zero dependencies, in the style of [`crate::testkit`]: a
//! character-level masker ([`scan`]) feeds line/token rules ([`lint`]),
//! with a machine-readable JSON report and a `rust/lint-allow.txt`
//! allowlist for the justified survivors. Wired as a blocking CI job
//! and exercised by seeded-violation fixtures in
//! `rust/tests/lint_analysis.rs`.

pub mod lint;
pub mod scan;

pub use lint::{lint_source, lint_tree, LintContext, LintReport, Violation, RULES};
pub use scan::{mask_lines, MaskedLine};
