//! Repo-specific source lints over `rust/src` (`caraserve lint`).
//!
//! Seven rules, all motivated by the concurrency-heavy subsystems this
//! tree grew in PRs 2–5 (and the wire codec of PR 9):
//!
//! - **safety-comment** — every line containing the `unsafe` keyword
//!   must have a `// SAFETY:` comment on the same line or in the
//!   contiguous block of comment-only lines directly above it.
//! - **ordering-comment** — every `Ordering::Relaxed` outside test
//!   code must carry a nearby `// ORDERING:` justification (Relaxed on
//!   a data-carrying atomic is exactly the PR 2 class of bug).
//! - **hot-unwrap** — no `.unwrap()` / `.expect(` in non-test code of
//!   the hot-path modules (`ipc/`, `runtime/`, `cpu_lora/`, and the
//!   engine/kvcache/batcher files). The mutex-poisoning idiom
//!   `.lock().unwrap()` (and `.read()`/`.write()`) is tolerated;
//!   other survivors go in `rust/lint-allow.txt` with justification.
//! - **decode-sleep** — no `std::thread::sleep` or `spin_loop` in the
//!   decode-path modules outside tests (a stray sleep there is a
//!   latency bug, not a style issue).
//! - **unsafe-op-deny** — the crate root must enforce
//!   `#![deny(unsafe_op_in_unsafe_fn)]`.
//! - **wire-panic-free** — no panicking construct (`unwrap`/`expect`/
//!   `panic!`/`unreachable!`/asserts/…) in non-test code of the wire
//!   codec (`remote/wire.rs`): the decoder consumes untrusted bytes
//!   off a socket, so every malformed input must surface as a typed
//!   `WireError`, never a panic.
//! - **undeclared-crate** — every snake-case `root::…` path must
//!   resolve to a declared dependency, a module in the tree, or a
//!   `use`-imported name (this rule is what catches an extern crate
//!   referenced without a manifest entry — a build break the linter
//!   can flag without running cargo).
//!
//! Rules scan the masked per-line view from [`super::scan`], so
//! keywords inside strings or doc comments never fire. The allowlist
//! file `rust/lint-allow.txt` holds `rule :: path-suffix :: needle`
//! entries matched against the violation's file and source text —
//! line-number-free so entries survive unrelated edits.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::scan;

/// All rule names, in reporting order.
pub const RULES: &[&str] = &[
    "safety-comment",
    "ordering-comment",
    "hot-unwrap",
    "decode-sleep",
    "wire-panic-free",
    "unsafe-op-deny",
    "undeclared-crate",
];

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Rule name (one of [`RULES`]).
    pub rule: &'static str,
    /// Path relative to `rust/src`, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Trimmed source text of the offending line.
    pub text: String,
}

/// Cross-file context the per-file rules need: which path roots are
/// legal (declared crates, modules in the tree).
#[derive(Debug, Clone, Default)]
pub struct LintContext {
    /// Module names under `rust/src`: directory names, file stems, and
    /// inline `mod` declarations.
    pub modules: BTreeSet<String>,
    /// Declared dependency crates + the crate's own name + tool
    /// attribute namespaces (`clippy`, `rustfmt`).
    pub crates: BTreeSet<String>,
}

const KEYWORD_ROOTS: &[&str] = &["std", "core", "alloc", "crate", "self", "super"];
const PRIMITIVE_ROOTS: &[&str] = &[
    "f32", "f64", "u8", "u16", "u32", "u64", "u128", "i8", "i16", "i32", "i64",
    "i128", "usize", "isize", "bool", "char", "str",
];

/// Hot-path modules for the unwrap rule.
fn is_hot_path(rel: &str) -> bool {
    rel.starts_with("ipc/")
        || rel.starts_with("runtime/")
        || rel.starts_with("cpu_lora/")
        || matches!(
            rel,
            "server/engine.rs" | "server/kvcache.rs" | "server/batcher.rs"
        )
}

/// Constructs the wire codec must never contain outside tests: the
/// decoder runs on untrusted bytes straight off a socket, so every
/// failure must come back as a typed `WireError`, not a panic.
/// (`debug_assert` matches the `!`/`_eq!`/`_ne!` spellings; `assert!`
/// is listed after `debug_assert` only for reporting clarity — one
/// violation per line, first matching pattern wins.)
const WIRE_PANICKY: &[&str] = &[
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
    "debug_assert",
    "assert!",
    "assert_eq!",
    "assert_ne!",
    ".unwrap(",
    ".expect(",
];

/// The wire-codec files for the panic-free rule.
fn is_wire_codec(rel: &str) -> bool {
    rel.ends_with("remote/wire.rs")
}

/// Decode-path modules for the sleep/busy-spin rule.
fn is_decode_path(rel: &str) -> bool {
    rel.starts_with("kernels/")
        || rel.starts_with("runtime/")
        || matches!(
            rel,
            "server/engine.rs" | "server/batcher.rs" | "server/kvcache.rs"
        )
}

/// Snake-case identifiers appearing in `line` (used to harvest `use`
/// imports and `mod` declarations).
fn snake_idents(line: &str) -> Vec<String> {
    let spaced: String = line
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { ' ' })
        .collect();
    spaced
        .split_whitespace()
        .filter(|t| {
            t.starts_with(|c: char| c.is_ascii_lowercase() || c == '_')
                && t.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        })
        .map(str::to_string)
        .collect()
}

/// `mod NAME` declarations on a masked code line.
fn mod_decls(code: &str) -> Vec<String> {
    let toks = snake_idents(code);
    toks.windows(2)
        .filter(|w| w[0] == "mod")
        .map(|w| w[1].clone())
        .collect()
}

/// Lint one file's source. `rel` is the path relative to `rust/src`
/// with `/` separators; it selects the hot/decode path rules.
pub fn lint_source(rel: &str, src: &str, ctx: &LintContext) -> Vec<Violation> {
    let lines = scan::mask_lines(src);
    let raw: Vec<&str> = src.lines().collect();
    let raw_at = |i: usize| raw.get(i).copied().unwrap_or("").trim();
    let test_start = raw
        .iter()
        .position(|l| l.contains("#[cfg(test)]"))
        .unwrap_or(usize::MAX);
    let mut imports: BTreeSet<String> = BTreeSet::new();
    for l in &raw {
        let t = l.trim_start();
        if t.starts_with("use ") || t.starts_with("pub use ") {
            imports.extend(snake_idents(t));
        }
    }
    let hot = is_hot_path(rel);
    let decode = is_decode_path(rel);
    let wire = is_wire_codec(rel);
    let mut out = Vec::new();
    let mut push = |rule: &'static str, line: usize, text: String| {
        out.push(Violation {
            rule,
            file: rel.to_string(),
            line,
            text,
        });
    };
    for (i, ml) in lines.iter().enumerate() {
        let intest = i >= test_start;
        // A justification tag counts if it is on the same line or in the
        // contiguous run of comment-only lines directly above (a code or
        // blank line breaks the run, so stale far-away tags don't count).
        let near = |tag: &str| {
            if lines[i].comment.contains(tag) {
                return true;
            }
            let mut j = i;
            while j > 0 {
                j -= 1;
                let above = &lines[j];
                if !above.code.trim().is_empty() || above.comment.trim().is_empty() {
                    return false;
                }
                if above.comment.contains(tag) {
                    return true;
                }
            }
            false
        };
        if scan::contains_word(&ml.code, "unsafe") && !near("SAFETY:") {
            push("safety-comment", i + 1, raw_at(i).to_string());
        }
        if !intest && ml.code.contains("Ordering::Relaxed") && !near("ORDERING:") {
            push("ordering-comment", i + 1, raw_at(i).to_string());
        }
        if hot && !intest {
            let stripped = scan::strip_ws(&ml.code);
            let prev = if i > 0 {
                scan::strip_ws(&lines[i - 1].code)
            } else {
                String::new()
            };
            for pat in [".unwrap()", ".expect("] {
                let mut from = 0;
                while let Some(p) = stripped[from..].find(pat) {
                    let at = from + p;
                    // The poisoning idiom: unwrapping a lock guard is
                    // the accepted way to propagate panics, even when
                    // the call spans a line break.
                    let before = format!("{prev}{}", &stripped[..at]);
                    let lock_idiom = [".lock()", ".read()", ".write()"]
                        .iter()
                        .any(|suf| before.ends_with(suf));
                    if !lock_idiom {
                        push("hot-unwrap", i + 1, raw_at(i).to_string());
                    }
                    from = at + 1;
                }
            }
        }
        if decode
            && !intest
            && (ml.code.contains("thread::sleep") || ml.code.contains("spin_loop"))
        {
            push("decode-sleep", i + 1, raw_at(i).to_string());
        }
        if wire && !intest && WIRE_PANICKY.iter().any(|pat| ml.code.contains(pat)) {
            push("wire-panic-free", i + 1, raw_at(i).to_string());
        }
        if !intest {
            for root in scan::path_roots(&ml.code) {
                let allowed = KEYWORD_ROOTS.contains(&root.as_str())
                    || PRIMITIVE_ROOTS.contains(&root.as_str())
                    || ctx.crates.contains(&root)
                    || ctx.modules.contains(&root)
                    || imports.contains(&root);
                if !allowed {
                    let shown: String = raw_at(i).chars().take(70).collect();
                    push("undeclared-crate", i + 1, format!("{root} :: {shown}"));
                }
            }
        }
    }
    out
}

/// One allowlist entry: `rule :: path-suffix :: needle`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllowEntry {
    pub rule: String,
    pub path: String,
    pub needle: String,
}

impl AllowEntry {
    fn matches(&self, v: &Violation) -> bool {
        v.rule == self.rule && v.file.ends_with(&self.path) && v.text.contains(&self.needle)
    }
}

/// Parse an allowlist file: one `rule :: path-suffix :: needle` entry
/// per line; `#` comments and blank lines skipped. Malformed lines are
/// returned as errors so a typo can't silently allow everything.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = t.split(" :: ").collect();
        if parts.len() != 3 {
            return Err(format!(
                "lint-allow.txt:{}: expected `rule :: path-suffix :: needle`, got {t:?}",
                i + 1
            ));
        }
        out.push(AllowEntry {
            rule: parts[0].trim().to_string(),
            path: parts[1].trim().to_string(),
            needle: parts[2].trim().to_string(),
        });
    }
    Ok(out)
}

/// Result of a full-tree lint run.
#[derive(Debug)]
pub struct LintReport {
    /// The `rust/src` root that was scanned.
    pub root: String,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Surviving (non-allowlisted) violations.
    pub violations: Vec<Violation>,
    /// Number of findings suppressed by the allowlist.
    pub allowed: usize,
    /// Allowlist entries that matched nothing (candidates for removal).
    pub unused_allow: Vec<String>,
}

impl LintReport {
    /// True when no violations survived the allowlist.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Machine-readable report (stable key order).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("root".into(), Json::Str(self.root.clone())),
            ("files_scanned".into(), Json::Num(self.files_scanned as f64)),
            (
                "rules".into(),
                Json::Arr(RULES.iter().map(|r| Json::Str((*r).into())).collect()),
            ),
            (
                "violation_count".into(),
                Json::Num(self.violations.len() as f64),
            ),
            ("allowed".into(), Json::Num(self.allowed as f64)),
            ("clean".into(), Json::Bool(self.is_clean())),
            (
                "violations".into(),
                Json::Arr(
                    self.violations
                        .iter()
                        .map(|v| {
                            Json::Obj(vec![
                                ("rule".into(), Json::Str(v.rule.into())),
                                ("file".into(), Json::Str(v.file.clone())),
                                ("line".into(), Json::Num(v.line as f64)),
                                ("text".into(), Json::Str(v.text.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "unused_allowlist".into(),
                Json::Arr(
                    self.unused_allow
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable table.
    pub fn render_table(&self) -> String {
        let mut s = String::new();
        if !self.violations.is_empty() {
            let rule_w = self
                .violations
                .iter()
                .map(|v| v.rule.len())
                .max()
                .unwrap_or(4);
            let loc_w = self
                .violations
                .iter()
                .map(|v| v.file.len() + 1 + v.line.to_string().len())
                .max()
                .unwrap_or(8);
            for v in &self.violations {
                let loc = format!("{}:{}", v.file, v.line);
                s.push_str(&format!(
                    "{:<rule_w$}  {:<loc_w$}  {}\n",
                    v.rule, loc, v.text
                ));
            }
        }
        for u in &self.unused_allow {
            s.push_str(&format!("warning: unused allowlist entry: {u}\n"));
        }
        s.push_str(&format!(
            "{} file(s) scanned, {} violation(s), {} allowlisted — {}\n",
            self.files_scanned,
            self.violations.len(),
            self.allowed,
            if self.is_clean() { "clean" } else { "FAIL" }
        ));
        s
    }
}

fn collect_rs_files(root: &Path) -> anyhow::Result<Vec<(String, PathBuf)>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let p = entry?.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                let rel = p
                    .strip_prefix(root)
                    .unwrap_or(&p)
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push((rel, p));
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Declared dependency names (plus the package's own name) from the
/// workspace `Cargo.toml`, and the tool attribute namespaces.
fn declared_crates(manifest: &str) -> BTreeSet<String> {
    let mut crates: BTreeSet<String> =
        ["clippy", "rustfmt"].iter().map(|s| s.to_string()).collect();
    let mut section = String::new();
    for line in manifest.lines() {
        let t = line.trim();
        if t.starts_with('[') {
            section = t.trim_matches(|c| c == '[' || c == ']').to_string();
            continue;
        }
        if section == "dependencies" || section == "dev-dependencies" {
            if let Some((k, _)) = t.split_once('=') {
                let k = k.trim();
                if !k.is_empty() && !k.starts_with('#') {
                    crates.insert(k.replace('-', "_"));
                }
            }
        } else if section == "package" && t.starts_with("name") {
            if let Some((_, v)) = t.split_once('=') {
                crates.insert(v.trim().trim_matches('"').replace('-', "_"));
            }
        }
    }
    crates
}

/// Lint the whole tree under `repo_root` (the directory holding
/// `Cargo.toml`, `rust/src`, and optionally `rust/lint-allow.txt`).
pub fn lint_tree(repo_root: &Path) -> anyhow::Result<LintReport> {
    let src_root = repo_root.join("rust").join("src");
    anyhow::ensure!(
        src_root.is_dir(),
        "no rust/src directory under {}",
        repo_root.display()
    );
    let files = collect_rs_files(&src_root)?;
    let mut sources = Vec::with_capacity(files.len());
    for (rel, path) in &files {
        sources.push((rel.clone(), std::fs::read_to_string(path)?));
    }

    let mut ctx = LintContext::default();
    let manifest_path = repo_root.join("Cargo.toml");
    if manifest_path.is_file() {
        ctx.crates = declared_crates(&std::fs::read_to_string(&manifest_path)?);
    }
    for (rel, src) in &sources {
        for seg in rel.split('/') {
            if let Some(stem) = seg.strip_suffix(".rs") {
                ctx.modules.insert(stem.to_string());
            } else {
                ctx.modules.insert(seg.to_string());
            }
        }
        for ml in scan::mask_lines(src) {
            for m in mod_decls(&ml.code) {
                ctx.modules.insert(m);
            }
        }
    }

    let mut violations = Vec::new();
    for (rel, src) in &sources {
        violations.extend(lint_source(rel, src, &ctx));
    }
    // Crate-root policy: unsafe blocks inside unsafe fns must be
    // explicit everywhere, enforced from lib.rs.
    match sources.iter().find(|(rel, _)| rel == "lib.rs") {
        Some((_, lib)) if lib.contains("#![deny(unsafe_op_in_unsafe_fn)]") => {}
        _ => violations.push(Violation {
            rule: "unsafe-op-deny",
            file: "lib.rs".into(),
            line: 1,
            text: "missing #![deny(unsafe_op_in_unsafe_fn)] at crate root".into(),
        }),
    }

    let allow_path = repo_root.join("rust").join("lint-allow.txt");
    let entries = if allow_path.is_file() {
        parse_allowlist(&std::fs::read_to_string(&allow_path)?)
            .map_err(|e| anyhow::anyhow!(e))?
    } else {
        Vec::new()
    };
    let mut used = vec![false; entries.len()];
    let mut survivors = Vec::new();
    let mut allowed = 0usize;
    for v in violations {
        match entries.iter().position(|e| e.matches(&v)) {
            Some(k) => {
                used[k] = true;
                allowed += 1;
            }
            None => survivors.push(v),
        }
    }
    let unused_allow = entries
        .iter()
        .zip(&used)
        .filter(|(_, &u)| !u)
        .map(|(e, _)| format!("{} :: {} :: {}", e.rule, e.path, e.needle))
        .collect();

    Ok(LintReport {
        root: src_root.display().to_string(),
        files_scanned: sources.len(),
        violations: survivors,
        allowed,
        unused_allow,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> LintContext {
        let mut c = LintContext::default();
        c.crates.extend(["anyhow", "libc"].map(String::from));
        c.modules.extend(["util", "ipc"].map(String::from));
        c
    }

    #[test]
    fn unsafe_without_safety_comment_fires() {
        let src = "fn f() {\n    unsafe { g() }\n}\n";
        let v = lint_source("ipc/x.rs", src, &ctx());
        assert!(v.iter().any(|v| v.rule == "safety-comment" && v.line == 2));
    }

    #[test]
    fn safety_comment_in_contiguous_block_above_passes() {
        // A multi-line comment block with the tag on its first line is
        // fine no matter how long it runs.
        let src = "// SAFETY: checked above,\n// with several lines\n// of explanation\n// before the block.\nunsafe { g() }\n";
        let v = lint_source("ipc/x.rs", src, &ctx());
        assert!(!v.iter().any(|v| v.rule == "safety-comment"));
        // A code line between the tag and the unsafe breaks the run.
        let src = "// SAFETY: stale, belongs to f.\nfn f() {}\nunsafe { g() }\n";
        let v = lint_source("ipc/x.rs", src, &ctx());
        assert!(v.iter().any(|v| v.rule == "safety-comment"));
        // Same-line trailing comments count too.
        let src = "unsafe { g() } // SAFETY: g has no preconditions.\n";
        let v = lint_source("ipc/x.rs", src, &ctx());
        assert!(!v.iter().any(|v| v.rule == "safety-comment"));
    }

    #[test]
    fn relaxed_needs_ordering_comment_outside_tests() {
        let src = "let x = a.load(Ordering::Relaxed);\n";
        assert!(lint_source("server/api.rs", src, &ctx())
            .iter()
            .any(|v| v.rule == "ordering-comment"));
        let ok = "// ORDERING: counter only; no data published.\nlet x = a.load(Ordering::Relaxed);\n";
        assert!(!lint_source("server/api.rs", ok, &ctx())
            .iter()
            .any(|v| v.rule == "ordering-comment"));
        let in_test = "#[cfg(test)]\nmod t {\n    fn f() { a.load(Ordering::Relaxed); }\n}\n";
        assert!(!lint_source("server/api.rs", in_test, &ctx())
            .iter()
            .any(|v| v.rule == "ordering-comment"));
    }

    #[test]
    fn hot_unwrap_scoped_to_hot_paths_and_lock_idiom() {
        let src = "let v = x.unwrap();\nlet w = y.expect(\"w\");\n";
        let v = lint_source("ipc/x.rs", src, &ctx());
        assert_eq!(v.iter().filter(|v| v.rule == "hot-unwrap").count(), 2);
        // Same code outside a hot path is fine.
        assert!(lint_source("sim/x.rs", src, &ctx()).is_empty());
        // Lock poisoning idiom tolerated, even across a line break.
        let lock = "let g = m.lock().unwrap();\nlet h = m\n    .read()\n    .unwrap();\n";
        assert!(!lint_source("runtime/x.rs", lock, &ctx())
            .iter()
            .any(|v| v.rule == "hot-unwrap"));
    }

    #[test]
    fn decode_sleep_fires_in_decode_modules() {
        let src = "std::thread::sleep(d);\n";
        assert!(lint_source("runtime/native.rs", src, &ctx())
            .iter()
            .any(|v| v.rule == "decode-sleep"));
        assert!(!lint_source("sim/front.rs", src, &ctx())
            .iter()
            .any(|v| v.rule == "decode-sleep"));
    }

    #[test]
    fn wire_panic_rule_fires_only_in_the_wire_codec() {
        let src = "let n = bytes.first().unwrap();\npanic!(\"bad tag\");\n";
        let v = lint_source("remote/wire.rs", src, &ctx());
        assert_eq!(v.iter().filter(|v| v.rule == "wire-panic-free").count(), 2);
        // Identical code elsewhere (even hot paths) is judged by the
        // other rules, not this one.
        assert!(!lint_source("remote/client.rs", src, &ctx())
            .iter()
            .any(|v| v.rule == "wire-panic-free"));
        // Test code in the codec file may assert freely.
        let in_test = format!("#[cfg(test)]\nmod t {{\n{src}}}\n");
        assert!(lint_source("remote/wire.rs", &in_test, &ctx()).is_empty());
        // Strings and comments never fire (masked view).
        let masked = "// the decoder never calls .unwrap( here\nlet s = \"panic!\";\n";
        assert!(lint_source("remote/wire.rs", masked, &ctx()).is_empty());
    }

    #[test]
    fn undeclared_crate_root_fires_and_known_roots_pass() {
        let src = "let p = serde::to_string(&x);\n";
        let v = lint_source("util/x.rs", src, &ctx());
        assert!(v.iter().any(|v| v.rule == "undeclared-crate"));
        let ok = "use std::fmt;\nfn f() { fmt::format(args); libc::mmap(); ipc::shm::go(); }\n";
        assert!(lint_source("util/x.rs", ok, &ctx()).is_empty());
        // Strings and comments never fire.
        let masked = "let s = \"serde::json\"; // or toml::de\n";
        assert!(lint_source("util/x.rs", masked, &ctx()).is_empty());
    }

    #[test]
    fn allowlist_parses_and_matches() {
        let text = "# comment\n\nhot-unwrap :: server/engine.rs :: expect(\"resume\n";
        let entries = parse_allowlist(text).unwrap();
        assert_eq!(entries.len(), 1);
        let v = Violation {
            rule: "hot-unwrap",
            file: "server/engine.rs".into(),
            line: 7,
            text: "let t = r.expect(\"resume carries tokens\");".into(),
        };
        assert!(entries[0].matches(&v));
        assert!(parse_allowlist("only two :: fields\n").is_err());
    }

    #[test]
    fn report_json_shape() {
        let rep = LintReport {
            root: "rust/src".into(),
            files_scanned: 3,
            violations: vec![Violation {
                rule: "safety-comment",
                file: "ipc/shm.rs".into(),
                line: 9,
                text: "unsafe {".into(),
            }],
            allowed: 2,
            unused_allow: vec![],
        };
        let j = rep.to_json();
        assert_eq!(j.get("violation_count").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("clean").and_then(|v| v.as_bool()), Some(false));
        let first = &j.get("violations").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("file").and_then(|v| v.as_str()), Some("ipc/shm.rs"));
        assert!(rep.render_table().contains("FAIL"));
    }
}
