//! CaraServe CLI — leader entrypoint.
//!
//! Subcommands:
//!
//! - `serve`     — serve a synthetic batch of requests through a real
//!   runtime (native pure-Rust backend by default, PJRT artifacts when
//!   built), with the CPU-assisted cold-start path live when
//!   `--cpu-workers > 0`, printing metrics incl. the TTFT cold-start
//!   breakdown.
//! - `cluster`   — the §5 scheduler in front of *real* engines: route a
//!   heterogeneous-rank synthetic workload (mixed ranks, mixed SLOs,
//!   cold and warm adapters; `--skew` for a Zipf popularity head)
//!   across N native-runtime `InferenceServer`s through a
//!   `ClusterFront`, per `--policy` (or several, comma-separated, or
//!   `all`), printing per-policy TTFT/TPOT percentiles, SLO attainment,
//!   per-server load balance, cold-start counts, and preemptions.
//!   `--smoke` is the small CI configuration.
//! - `coordinator` — the §3 global coordinator over the same live
//!   cluster: registry-driven placement (popularity × rank × slot
//!   pressure), pre-warming of the `--prewarm` hottest adapters, and
//!   runtime migration every `--migrate-interval` polls — compared
//!   head-to-head against the static placement baseline on a skewed
//!   (`--skew`) workload, printing both rows plus the coordinator's
//!   placement/migration counters. `--smoke` is the CI configuration.
//! - `chaos`     — the failover acceptance drill: the same live cluster
//!   with a fault plan injected into one backend (default: a seeded
//!   panic mid-decode), reconciled stream-for-stream against a no-fault
//!   oracle run. Exits non-zero if any completed stream diverged from
//!   the oracle or a panic escaped containment. `--smoke` is the CI
//!   configuration.
//! - `artifacts` — the content-addressed adapter store pipeline:
//!   `seed` publishes the synthetic catalog as manifests + SHA-256
//!   blobs, `push`/`pull` stream digest-verified chunks to/from a
//!   running backend, `verify` re-hashes the store, `gc` collects
//!   unreferenced blobs.
//! - `simulate`  — run a single-instance simulation of one §7.2 workload.
//! - `schedule`  — run the §7.5 cluster scheduling simulation.
//! - `profile`   — fit the §5 performance models and print (α, β, R²).
//! - `info`      — print model/GPU tables (paper Table 2).
//! - `lint`      — run the repo-specific static analysis
//!   ([`caraserve::analysis`]) over `rust/src`: SAFETY/ORDERING comment
//!   coverage, hot-path unwraps, decode-path sleeps, crate-root policy,
//!   and undeclared path roots. Exits non-zero on any violation that is
//!   not allowlisted in `rust/lint-allow.txt`; `--json PATH` writes the
//!   machine-readable report (CI gates on this subcommand).

use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::perfmodel::{profiler, KernelKind, PerfModel};
use caraserve::scheduler::{policy_by_name, RankAwareConfig};
use caraserve::sim::{
    GpuModel, MafTrace, ServingMode, SimInstance, Simulation, SingleServer,
};
use caraserve::util::cli::Args;
use caraserve::util::stats::{mean, Summary};

const USAGE: &str = "\
caraserve <subcommand> [options]

subcommands:
  serve     --runtime auto|native|pjrt --artifacts DIR --requests N
            --mode cached|ondemand|caraserve --cpu-workers N
            --threads N --load-scale F --slo-ttft-ms F --slo-tpot-ms F
            --remote SOCK[,SOCK...] --http HOST:PORT --soak N --smoke
            --store DIR
            (with --remote, `serve` becomes the router process: a
             ClusterFront over RemoteFronts speaking the wire protocol
             to `caraserve backend` processes; with --store, installs
             and migrations stream real weights to backends by digest
             before the install frame lands)
  backend   --socket PATH --name NAME --adapters N --threads N
            --kv-pages N --mode cached|ondemand|caraserve --sim
            --store DIR
            (host one engine behind the wire protocol on a unix
             socket, in its own OS process; exits on a router
             Shutdown frame; with --store, installs load weights
             from the content-addressed artifact store — synthetic
             seeding only when the store has no manifest — and the
             wire serves artifact fetch/push frames from it)
  artifacts seed   --store DIR --adapters N --hidden N
            push   --store DIR --socket PATH --adapter N
            pull   --store DIR --socket PATH --adapter N
            verify --store DIR
            gc     --store DIR
            (content-addressed adapter store: a JSON manifest per
             adapter pointing at SHA-256-addressed blobs, deduped
             across adapters; push/pull stream digest-verified
             chunks to/from a running backend)
  cluster   --instances N --policy rank-aware|most-idle|first-fit|random
            (comma-separate or `all` for several) --requests N
            --adapters N --mode cached|ondemand|caraserve --cpu-workers N
            --threads N --kv-pages N --pool-pages N --pace N --seed N
            --skew F --smoke
  coordinator --instances N --policy NAME --requests N --adapters N
            --skew F --migrate-interval N --prewarm K --replicas N
            --mode cached|ondemand|caraserve --cpu-workers N --threads N
            --kv-pages N --pool-pages N --pace N --seed N --smoke
  chaos     --instances N --policy NAME --requests N --adapters N
            --fault [server:]kind@site:n[,...] --seed N --retries N
            --mode cached|ondemand|caraserve --kv-pages N --pool-pages N
            --pace N --smoke
            (fault kinds: panic|error|die|stall|slow; sites:
             submit|poll|decode|load; default: seeded panic mid-decode
             on server 0; exits non-zero on any diverged stream)
  simulate  --mode cached|ondmd|s-lora|caraserve --rps F --rank N --secs F
  schedule  --policy rank-aware|most-idle|first-fit|random --instances N
            --kernel bgmv|mbgmv --rps F --secs F
  profile   --kernel bgmv|mbgmv
  lint      --root DIR --json PATH   (non-zero exit on violations)
  info

--pool-pages N sizes the unified device pool that adapter weights and
KV pages share on the native runtime — it overrides --kv-pages, and
under `coordinator` additionally switches placement to the memory-aware
scorer that weighs adapter page footprints.

distributed serving (two backends + a router with an HTTP front door):

  caraserve backend --socket /tmp/b0.sock --name b0 &
  caraserve backend --socket /tmp/b1.sock --name b1 &
  caraserve serve --remote /tmp/b0.sock,/tmp/b1.sock --http 127.0.0.1:8090 &
  curl -N -X POST http://127.0.0.1:8090/v1/requests \\
       -d '{\"adapter\": 3, \"prompt\": [1, 2, 3], \"max_new_tokens\": 8}'
  curl http://127.0.0.1:8090/v1/stats

POST /v1/requests streams one JSON event per line (chunked transfer);
DELETE /v1/requests/<id> cancels; GET /v1/stats reports aggregated
cluster stats. `--soak N` drives N concurrent streaming clients
against the front door and verifies every stream ends in exactly one
terminal event. A killed backend rejoins with its adapters intact
(reconnect-with-state); one that lost them is re-installed from the
registry's placements before readmission.

artifact pipeline (seed a store, stream weights between processes):

  caraserve artifacts seed --store /tmp/router-store --adapters 8
  caraserve backend --socket /tmp/b0.sock --store /tmp/b0-store \\
       --adapters 0 &
  caraserve artifacts push --store /tmp/router-store \\
       --socket /tmp/b0.sock --adapter 3
  caraserve artifacts pull --store /tmp/fresh-store \\
       --socket /tmp/b0.sock --adapter 3
  caraserve artifacts verify --store /tmp/fresh-store
  caraserve artifacts gc --store /tmp/router-store

Blobs are SHA-256-addressed: adapters sharing weights store each blob
once, pushes skip blobs the receiver already holds, and every chunk is
digest-checked in flight. A router started with `--store` streams the
store's weights to backends on install and migration, so a migration
target seeds nothing synthetically — its engine loads the exact bytes
the source served (TTFT overlaps transfer with the CPU-assist window:
max(transfer, prefill), not their sum).
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env(&[
        "artifacts",
        "requests",
        "mode",
        "runtime",
        "cpu-workers",
        "threads",
        "load-scale",
        "rps",
        "rank",
        "secs",
        "policy",
        "instances",
        "adapters",
        "kv-pages",
        "pool-pages",
        "pace",
        "kernel",
        "seed",
        "slo-ttft-ms",
        "slo-tpot-ms",
        "skew",
        "fault",
        "retries",
        "migrate-interval",
        "prewarm",
        "replicas",
        "root",
        "json",
        "socket",
        "name",
        "remote",
        "http",
        "soak",
        "store",
        "hidden",
        "adapter",
    ])
    .map_err(|e| anyhow::anyhow!("{e}"))?;

    match args.subcommand() {
        Some("serve") => cmd_serve(&args),
        Some("backend") => cmd_backend(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("coordinator") => cmd_coordinator(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("schedule") => cmd_schedule(&args),
        Some("profile") => cmd_profile(&args),
        Some("lint") => cmd_lint(&args),
        Some("info") => cmd_info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use caraserve::model::LoraSpec;
    use caraserve::runtime::{NativeConfig, NativeRuntime, Runtime};
    use caraserve::server::{
        ColdStartMode, EngineConfig, InferenceServer, LifecycleState, ServeRequest,
        ServingFront,
    };
    // `--remote` flips `serve` into the distributed router role.
    if args.opt("remote").is_some() {
        return cmd_serve_remote(args);
    }
    let dir = args.opt_or("artifacts", "artifacts");
    let n: usize = args.opt_parse_or("requests", 16).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mode = match args.opt_or("mode", "caraserve").as_str() {
        "cached" => ColdStartMode::Cached,
        "ondemand" | "ondmd" => ColdStartMode::OnDemand,
        _ => ColdStartMode::CaraServe,
    };
    let seed: u64 = args.opt_parse_or("seed", 1).map_err(|e| anyhow::anyhow!("{e}"))?;
    let workers: usize = args
        .opt_parse_or("cpu-workers", 4)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let load_scale: f64 = args
        .opt_parse_or("load-scale", 1.0)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    // Forward-pass worker threads for the native backend (batch rows
    // fan across these; output is bitwise independent of the width).
    let threads: usize = args
        .opt_parse_or("threads", caraserve::runtime::native::default_threads())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let native_cfg = || NativeConfig {
        threads,
        ..NativeConfig::tiny()
    };
    let slo_ttft: f64 = args
        .opt_parse_or("slo-ttft-ms", 200.0)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let slo_tpot: f64 = args
        .opt_parse_or("slo-tpot-ms", 50.0)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // Pick the backend: PJRT when artifacts are built (or demanded),
    // otherwise the native pure-Rust runtime — which also carries the
    // real CPU-assisted cold-start path.
    let manifest = std::path::Path::new(&dir).join("manifest.json");
    let runtime: Runtime = match args.opt_or("runtime", "auto").as_str() {
        "pjrt" => {
            println!("loading artifacts from {dir} ...");
            caraserve::runtime::ModelRuntime::load(std::path::Path::new(&dir))?.into()
        }
        "native" => NativeRuntime::new(native_cfg()).into(),
        "auto" if manifest.exists() => {
            println!("loading artifacts from {dir} ...");
            caraserve::runtime::ModelRuntime::load(std::path::Path::new(&dir))?.into()
        }
        "auto" => {
            println!("no artifacts at {dir}; using the native runtime");
            NativeRuntime::new(native_cfg()).into()
        }
        other => anyhow::bail!("unknown --runtime {other} (use auto|native|pjrt)"),
    };
    let mut server = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: mode,
            load_scale,
            ..Default::default()
        },
    )?;
    for id in 0..64u64 {
        server.install_adapter(&LoraSpec::standard(id, 8, "tiny"))?;
    }
    // Only CaraServe on a backend with the per-layer seam ever plans an
    // assist row — don't spawn worker threads the run can't use.
    if workers > 0
        && mode == ColdStartMode::CaraServe
        && server.runtime.supports_cpu_assist()
    {
        server.enable_cpu_assist(workers)?;
    }
    if mode == ColdStartMode::CaraServe {
        println!(
            "CaraServe cold starts: {}",
            if server.cpu_assist_active() {
                "real CPU-assisted path (shm worker pool)"
            } else {
                "modeled overlap (no per-layer seam on this backend)"
            }
        );
    }

    let mut rng = caraserve::util::rng::Rng::new(seed);
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(n);
    for _ in 0..n {
        let prompt: Vec<i32> = (0..rng.range(8, 32))
            .map(|_| rng.range(0, 1024) as i32)
            .collect();
        let req = ServeRequest::new(rng.range(0, 64) as u64, prompt)
            .max_new_tokens(rng.range(4, 16))
            .slo(slo_ttft, slo_tpot);
        handles.push(server.submit(req));
    }
    server.run_until_idle()?;
    let wall = t0.elapsed().as_secs_f64();

    let finished = handles
        .iter()
        .filter(|h| h.state() == LifecycleState::Finished)
        .count();
    anyhow::ensure!(finished == n, "only {finished}/{n} requests finished");

    // The paper's §7 headline metrics, from the real run.
    let m = server.metrics();
    for metric in ["ttft", "tpot", "latency"] {
        if let Some(s) = m.summary(metric) {
            println!(
                "{metric:>8}: mean {:.2} ms  p50 {:.2} ms  p99 {:.2} ms",
                s.mean * 1e3,
                s.p50 * 1e3,
                s.p99 * 1e3
            );
        }
    }
    // TTFT cold-start decomposition (§4): what the load window, prefill
    // compute, and CPU-assist time each contributed.
    for metric in ["ttft_load", "ttft_prefill", "ttft_assist"] {
        if let Some(s) = m.summary(metric) {
            println!(
                "{metric:>12}: mean {:.2} ms  p99 {:.2} ms",
                s.mean * 1e3,
                s.p99 * 1e3
            );
        }
    }
    let cs = m.cold_start();
    println!(
        "cold starts: {} cold / {} warm admits, {} CPU-assisted, {} handoffs, \
         {} deferred collisions, {:.2} ms decode-assist",
        cs.cold_admits,
        cs.warm_admits,
        cs.cpu_assisted,
        cs.handoffs,
        cs.deferred_collisions,
        cs.assist_decode_s * 1e3
    );
    if let Some(att) = m.slo_attainment() {
        println!(
            "SLO (ttft ≤ {slo_ttft} ms, tpot ≤ {slo_tpot} ms): attainment {:.1}%",
            att * 100.0
        );
    }
    let (rps, tps) = m.throughput(wall);
    println!("throughput: {rps:.1} req/s, {tps:.1} tok/s (mode {mode:?})");
    Ok(())
}

/// `caraserve backend`: host one engine behind the wire protocol on a
/// unix socket, in its own OS process. Routers started with
/// `caraserve serve --remote SOCK[,SOCK...]` connect to it; adapter
/// state persists across router connections (reconnect-with-state).
fn cmd_backend(args: &Args) -> anyhow::Result<()> {
    use caraserve::artifacts::ArtifactStore;
    use caraserve::model::LoraSpec;
    use caraserve::runtime::{NativeConfig, NativeRuntime};
    use caraserve::server::cluster::synthetic;
    use caraserve::server::{ColdStartMode, EngineConfig, InferenceServer, ServingFront};
    use caraserve::sim::SimFront;
    use std::sync::{Arc, Mutex};

    let socket = args
        .opt("socket")
        .ok_or_else(|| anyhow::anyhow!("backend requires --socket PATH"))?
        .to_string();
    let name = args.opt_or("name", "backend");
    let adapters: usize = args
        .opt_parse_or("adapters", 24)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mode = match args.opt_or("mode", "caraserve").as_str() {
        "cached" => ColdStartMode::Cached,
        "ondemand" | "ondmd" => ColdStartMode::OnDemand,
        _ => ColdStartMode::CaraServe,
    };
    let threads: usize = args
        .opt_parse_or("threads", 1)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let kv_pages: usize = args
        .opt_parse_or("kv-pages", 256)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    // `--store DIR` opens a content-addressed artifact store: installs
    // source weights from it (store hit) instead of synthetic seeding,
    // and the wire serves manifest/chunk fetch + push frames from it.
    let store: Option<Arc<Mutex<ArtifactStore>>> = match args.opt("store") {
        Some(dir) => Some(Arc::new(Mutex::new(ArtifactStore::open(
            std::path::Path::new(dir),
        )?))),
        None => None,
    };

    // `--sim` swaps in the deterministic simulator front (token streams
    // are the synthesized 0,1,2,… — handy for protocol debugging);
    // default is a real native engine, same construction as `cluster`.
    let mut front: Box<dyn ServingFront> = if args.flag("sim") {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::CaraServe, 32, 8, 64);
        Box::new(SimFront::new(inst, 512))
    } else {
        let native = NativeRuntime::new(NativeConfig {
            threads: threads.max(1),
            ..NativeConfig::tiny()
        });
        let mut engine = InferenceServer::new(
            native,
            EngineConfig {
                cold_start: mode,
                kv_pages,
                ..Default::default()
            },
        )?;
        if let Some(store) = &store {
            engine.attach_store(Arc::clone(store));
        }
        Box::new(engine)
    };
    for a in 0..adapters as u64 {
        front.install_adapter(&LoraSpec::standard(a, synthetic::rank_of(a), "tiny"))?;
    }

    let listener = caraserve::remote::bind(&socket)?;
    println!(
        "backend '{name}' on {socket}: {adapters} adapters (ranks {:?}), mode {mode:?}{}",
        synthetic::RANKS,
        if store.is_some() {
            ", artifact store attached"
        } else {
            ""
        }
    );
    caraserve::remote::serve_listener_with_store(
        front.as_mut(),
        &listener,
        &name,
        store.as_deref(),
    )
}

/// `caraserve artifacts <seed|push|pull|verify|gc>`: the adapter
/// artifact pipeline against a content-addressed store directory.
/// `seed` publishes the synthetic catalog (the same weights
/// `install_synthetic` seeds, so streamed installs are
/// bitwise-identical to in-process ones); `push`/`pull` stream an
/// adapter to/from a running `caraserve backend --store` over the
/// wire, deduped by blob digest; `verify` re-hashes every indexed
/// manifest and blob; `gc` drops unreferenced blobs.
fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    use caraserve::artifacts::{synthetic_stack, ArtifactStore};
    use caraserve::remote::RemoteFront;
    use caraserve::server::cluster::synthetic;
    use std::sync::{Arc, Mutex};

    let action = args
        .positional()
        .get(1)
        .map(String::as_str)
        .unwrap_or("")
        .to_string();
    let store_dir = args
        .opt("store")
        .ok_or_else(|| anyhow::anyhow!("artifacts requires --store DIR"))?
        .to_string();
    let mut store = ArtifactStore::open(std::path::Path::new(&store_dir))?;

    match action.as_str() {
        "seed" => {
            let adapters: usize = args
                .opt_parse_or("adapters", 24)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            // Default matches `NativeConfig::tiny()`, the backend the
            // distributed tier runs.
            let hidden: usize = args
                .opt_parse_or("hidden", 256)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            for a in 0..adapters as u64 {
                let rank = synthetic::rank_of(a);
                let digest = store.publish(a, rank, "tiny", &synthetic_stack(a, hidden, rank))?;
                println!("seeded adapter {a} rank {rank}: manifest {digest}");
            }
            println!(
                "store {store_dir}: {} adapters, {} blobs",
                store.len(),
                store.blob_count()?
            );
        }
        "push" | "pull" => {
            let socket = args
                .opt("socket")
                .ok_or_else(|| anyhow::anyhow!("artifacts {action} requires --socket PATH"))?;
            let adapter: u64 = args
                .opt_parse("adapter")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .ok_or_else(|| anyhow::anyhow!("artifacts {action} requires --adapter N"))?;
            let mut front = RemoteFront::connect(socket, "artifacts-cli")?;
            if action == "push" {
                let store = Arc::new(Mutex::new(store));
                front.attach_store(Arc::clone(&store));
                let mut session = front.push_session(adapter)?;
                let total = session.total_bytes();
                while !front.push_step(&mut session)? {}
                println!(
                    "pushed adapter {adapter}: manifest {}, {total} blob bytes \
                     after dedup ({} sent)",
                    session.manifest_digest(),
                    session.sent_bytes()
                );
            } else {
                let store = Mutex::new(store);
                let digest = front.pull_adapter(adapter, &store)?;
                let store = store.lock().unwrap();
                println!(
                    "pulled adapter {adapter}: manifest {digest}; store now \
                     {} adapters, {} blobs",
                    store.len(),
                    store.blob_count()?
                );
            }
        }
        "verify" => {
            let blobs = store.verify_all()?;
            println!(
                "store {store_dir}: {} manifests, {blobs} blobs — every digest matches",
                store.len()
            );
        }
        "gc" => {
            let collected = store.gc()?;
            println!("gc: {} unreferenced blobs collected", collected.len());
            for d in &collected {
                println!("  {d}");
            }
        }
        other => anyhow::bail!(
            "unknown artifacts action '{other}' (expected seed | push | pull | verify | gc)"
        ),
    }
    Ok(())
}

/// `caraserve serve --remote`: the router half of the distributed
/// tier. Builds a `ClusterFront` whose backends are `RemoteFront`s
/// speaking the wire protocol to `caraserve backend` processes, then
/// either drives the synthetic workload through it or serves the
/// HTTP/JSON front door (optionally self-soaking it with `--soak N`).
fn cmd_serve_remote(args: &Args) -> anyhow::Result<()> {
    use caraserve::remote::{HttpGateway, RemoteFront};
    use caraserve::scheduler::registry::{AdapterMeta, GlobalRegistry};
    use caraserve::server::cluster::{synthetic, ClusterFront};
    use caraserve::server::{LifecycleState, ServingFront};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let smoke = args.flag("smoke");
    let remote = args.opt_or("remote", "");
    let sockets: Vec<&str> = remote
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!sockets.is_empty(), "--remote needs at least one socket path");
    let adapters: usize = args
        .opt_parse_or("adapters", 24)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let requests: usize = args
        .opt_parse_or("requests", if smoke { 16 } else { 48 })
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed: u64 = args.opt_parse_or("seed", 1).map_err(|e| anyhow::anyhow!("{e}"))?;
    let pace: usize = args.opt_parse_or("pace", 2).map_err(|e| anyhow::anyhow!("{e}"))?;

    // `--store DIR` attaches a router-side artifact store: installs
    // (including rejoin re-installs and migrations) stream the real
    // weights to the backend by digest before the Install frame lands.
    let store = match args.opt("store") {
        Some(dir) => Some(Arc::new(std::sync::Mutex::new(
            caraserve::artifacts::ArtifactStore::open(std::path::Path::new(dir))?,
        ))),
        None => None,
    };

    let registry = Arc::new(GlobalRegistry::new());
    let mut backends: Vec<Box<dyn ServingFront>> = Vec::with_capacity(sockets.len());
    for (s, path) in sockets.iter().enumerate() {
        let mut front = RemoteFront::connect(*path, &format!("router#{s}"))?;
        if let Some(store) = &store {
            front.attach_store(Arc::clone(store));
        }
        println!("backend {s}: '{}' at {path}", front.server_name());
        backends.push(Box::new(front));
    }
    // The backends pre-install the same synthetic catalog; mirror it
    // (ids, ranks, placements) into the router's registry so routing
    // and rejoin re-installs see the same world. Adapters the artifact
    // store holds get a `cas:<manifest-digest>` weights path — the
    // durable pointer a registry save/load round-trips.
    for a in 0..adapters as u64 {
        let weights_path = match &store {
            Some(store) => store
                .lock()
                .unwrap()
                .manifest_of(a)
                .map(|(d, _)| format!("cas:{d}"))
                .unwrap_or_default(),
            None => String::new(),
        };
        registry.register(AdapterMeta {
            id: a,
            rank: synthetic::rank_of(a),
            base_model: "tiny".into(),
            weights_path,
        });
        for s in 0..sockets.len() {
            registry.place(a, s);
        }
    }
    let policy = synthetic::policy(&args.opt_or("policy", "rank-aware"), seed)?;
    let mut cluster = ClusterFront::new(backends, policy, registry);

    if let Some(http) = args.opt("http") {
        let gateway = HttpGateway::bind(http)?;
        let addr = gateway.addr();
        println!(
            "HTTP front door on http://{addr} (POST /v1/requests streams \
             events; DELETE /v1/requests/<id>; GET /v1/stats)"
        );
        let soak_clients: usize = args
            .opt_parse_or("soak", 0)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if soak_clients == 0 {
            // Serve until the process is killed.
            return gateway.run(&mut cluster, &|| false);
        }
        let per_client = if smoke { 2 } else { 4 };
        let done = Arc::new(AtomicBool::new(false));
        let soak_thread = {
            let done = done.clone();
            std::thread::spawn(move || {
                let rep =
                    caraserve::remote::soak(addr, soak_clients, per_client, adapters as u64, 8, 7);
                done.store(true, Ordering::SeqCst);
                rep
            })
        };
        gateway.run(&mut cluster, &|| done.load(Ordering::SeqCst))?;
        let rep = soak_thread.join().expect("soak harness panicked");
        println!(
            "soak: {} clients × {per_client} requests — {} completed, {} tokens, \
             {} cancelled, {} errors, {} dropped terminals, {} multi-terminals",
            rep.clients,
            rep.completed,
            rep.tokens,
            rep.cancelled,
            rep.errors,
            rep.dropped_terminals,
            rep.multi_terminals
        );
        anyhow::ensure!(rep.clean(), "soak saw dropped or duplicated terminal events");
        println!("event overflows: {}", cluster.stats().event_overflows);
        return Ok(());
    }

    // No HTTP front door: drive the synthetic workload through the
    // remote cluster directly — the distributed twin of `cluster`.
    let cfg = synthetic::SyntheticConfig {
        instances: sockets.len(),
        requests,
        adapters,
        seed,
        polls_per_arrival: pace,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(requests);
    for req in synthetic::workload(&cfg) {
        handles.push(cluster.submit(req));
        for _ in 0..pace {
            cluster.poll()?;
        }
    }
    cluster.run_until_idle()?;
    let wall = t0.elapsed().as_secs_f64();
    let finished = handles
        .iter()
        .filter(|h| h.state() == LifecycleState::Finished)
        .count();
    let rejected = handles
        .iter()
        .filter(|h| h.state() == LifecycleState::Rejected)
        .count();
    let tokens: usize = handles.iter().map(|h| h.tokens().len()).sum();
    println!(
        "distributed: {finished}/{requests} finished ({rejected} rejected), \
         {tokens} tokens in {wall:.2}s; routed {:?}; {} event overflows",
        cluster.routed(),
        cluster.stats().event_overflows
    );
    anyhow::ensure!(
        finished + rejected == requests,
        "{} streams never reached a terminal",
        requests - finished - rejected
    );
    Ok(())
}

fn cmd_cluster(args: &Args) -> anyhow::Result<()> {
    use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
    use caraserve::server::ColdStartMode;

    let smoke = args.flag("smoke");
    let mode = match args.opt_or("mode", "caraserve").as_str() {
        "cached" => ColdStartMode::Cached,
        "ondemand" | "ondmd" => ColdStartMode::OnDemand,
        _ => ColdStartMode::CaraServe,
    };
    let cfg = SyntheticConfig {
        instances: args
            .opt_parse_or("instances", 2)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        requests: args
            .opt_parse_or("requests", if smoke { 16 } else { 48 })
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        adapters: args
            .opt_parse_or("adapters", 24)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        seed: args.opt_parse_or("seed", 1).map_err(|e| anyhow::anyhow!("{e}"))?,
        threads: args
            .opt_parse_or("threads", 1)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        cpu_workers: args
            .opt_parse_or("cpu-workers", if smoke { 0 } else { 2 })
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        cold_start: mode,
        // `--pool-pages` names the same knob with unified-pool
        // semantics (adapter weights and KV share it on the native
        // runtime) and wins over the legacy `--kv-pages` spelling.
        kv_pages: match args
            .opt_parse("pool-pages")
            .map_err(|e| anyhow::anyhow!("{e}"))?
        {
            Some(pages) => pages,
            None => args
                .opt_parse_or("kv-pages", 256)
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        },
        polls_per_arrival: args
            .opt_parse_or("pace", 2)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        skew: args
            .opt_parse_or("skew", 0.0)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
    };
    let policy_arg = args.opt_or("policy", if smoke { "rank-aware,random" } else { "all" });
    let policies: Vec<&str> = match policy_arg.as_str() {
        "all" => vec!["rank-aware", "most-idle", "first-fit", "random"],
        list => list.split(',').map(str::trim).collect(),
    };

    println!(
        "cluster: {} native engines, {} requests, {} adapters (ranks {:?}), \
         mode {mode:?}, seed {}",
        cfg.instances,
        cfg.requests,
        cfg.adapters,
        synthetic::RANKS,
        cfg.seed
    );
    println!(
        "{:<12} {:>6} {:>9} {:>10} {:>10} {:>10} {:>10} {:>6} {:>8}  {}",
        "policy",
        "done",
        "SLO %",
        "ttft p50",
        "ttft p99",
        "tpot p50",
        "tpot p99",
        "cold",
        "preempt",
        "routed per server"
    );
    let ms = caraserve::util::stats::ms_or_dash;
    let mut attainment: Vec<(String, f64)> = Vec::new();
    for name in &policies {
        // run() itself reconciles finished + rejected == submitted.
        let rep = synthetic::run(name, &cfg)?;
        let att = rep.slo_attainment.unwrap_or(1.0);
        attainment.push((rep.policy.clone(), att));
        let routed: Vec<String> = rep
            .routed
            .iter()
            .zip(&rep.routed_rank_sum)
            .map(|(n, r)| format!("{n}(Σr{r})"))
            .collect();
        println!(
            "{:<12} {:>6} {:>8.1}% {:>10} {:>10} {:>10} {:>10} {:>6} {:>8}  {}",
            rep.policy,
            rep.finished,
            att * 100.0,
            ms(&rep.ttft, |s| s.p50),
            ms(&rep.ttft, |s| s.p99),
            ms(&rep.tpot, |s| s.p50),
            ms(&rep.tpot, |s| s.p99),
            rep.cold.cold_admits,
            rep.preemptions,
            routed.join(" ")
        );
    }
    let find = |n: &str| attainment.iter().find(|(p, _)| p == n).map(|&(_, a)| a);
    if let (Some(ra), Some(rnd)) = (find("rank-aware"), find("random")) {
        println!(
            "\nrank-aware {:.1}% vs random {:.1}% SLO attainment ({})",
            ra * 100.0,
            rnd * 100.0,
            if ra >= rnd { "rank-aware ≥ random ✓" } else { "rank-aware fell behind" }
        );
    }
    Ok(())
}

fn cmd_coordinator(args: &Args) -> anyhow::Result<()> {
    use caraserve::coordinator::CoordinatorConfig;
    use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
    use caraserve::server::ColdStartMode;

    let smoke = args.flag("smoke");
    let mode = match args.opt_or("mode", "caraserve").as_str() {
        "cached" => ColdStartMode::Cached,
        "ondemand" | "ondmd" => ColdStartMode::OnDemand,
        _ => ColdStartMode::CaraServe,
    };
    let cfg = SyntheticConfig {
        instances: args
            .opt_parse_or("instances", if smoke { 2 } else { 3 })
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        requests: args
            .opt_parse_or("requests", if smoke { 16 } else { 48 })
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        adapters: args
            .opt_parse_or("adapters", 16)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        seed: args.opt_parse_or("seed", 1).map_err(|e| anyhow::anyhow!("{e}"))?,
        threads: args
            .opt_parse_or("threads", 1)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        cpu_workers: args
            .opt_parse_or("cpu-workers", if smoke { 0 } else { 2 })
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        cold_start: mode,
        // `--pool-pages` sizes the unified pool (and wins over the
        // legacy `--kv-pages`); it also flips the coordinator below to
        // the memory-aware placement scorer.
        kv_pages: match args
            .opt_parse("pool-pages")
            .map_err(|e| anyhow::anyhow!("{e}"))?
        {
            Some(pages) => pages,
            None => args
                .opt_parse_or("kv-pages", 256)
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        },
        polls_per_arrival: args
            .opt_parse_or("pace", 1)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        // The coordinator exists for skewed demand: default to a real
        // Zipf head rather than the legacy mix.
        skew: args
            .opt_parse_or("skew", 1.2)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
    };
    let ccfg = CoordinatorConfig {
        migrate_interval: args
            .opt_parse_or("migrate-interval", 4)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        prewarm: args
            .opt_parse_or("prewarm", 4)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        // Two replicas per adapter by default — the same replication
        // factor as the static `hosts` baseline, so the comparison is
        // about *where* adapters live, not how many copies exist.
        replicas: args
            .opt_parse_or("replicas", 2)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        // With an explicit pool size the coordinator scores placements
        // by adapter page footprint against that budget (None keeps the
        // legacy slot-only scorer).
        pool_pages: args
            .opt_parse("pool-pages")
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        ..Default::default()
    };
    let policy = args.opt_or("policy", "rank-aware");

    println!(
        "coordinator: {} native engines, {} requests, {} adapters, skew {}, \
         mode {mode:?}, policy {policy}, migrate every {} polls, prewarm top-{}",
        cfg.instances,
        cfg.requests,
        cfg.adapters,
        cfg.skew,
        ccfg.migrate_interval,
        ccfg.prewarm
    );
    println!(
        "{:<12} {:>6} {:>9} {:>10} {:>10} {:>10} {:>10} {:>6} {:>8}  {}",
        "placement",
        "done",
        "SLO %",
        "ttft p50",
        "ttft p99",
        "tpot p50",
        "tpot p99",
        "cold",
        "preempt",
        "routed per server"
    );
    let ms = caraserve::util::stats::ms_or_dash;
    let print_row = |label: &str, rep: &synthetic::RunReport| {
        let routed: Vec<String> = rep
            .routed
            .iter()
            .zip(&rep.routed_rank_sum)
            .map(|(n, r)| format!("{n}(Σr{r})"))
            .collect();
        println!(
            "{:<12} {:>6} {:>8.1}% {:>10} {:>10} {:>10} {:>10} {:>6} {:>8}  {}",
            label,
            rep.finished,
            rep.slo_attainment.unwrap_or(1.0) * 100.0,
            ms(&rep.ttft, |s| s.p50),
            ms(&rep.ttft, |s| s.p99),
            ms(&rep.tpot, |s| s.p50),
            ms(&rep.tpot, |s| s.p99),
            rep.cold.cold_admits,
            rep.preemptions,
            routed.join(" ")
        );
    };

    let static_rep = synthetic::run(&policy, &cfg)?;
    print_row("static", &static_rep);
    let (coord_rep, coord) = synthetic::run_coordinated(&policy, &cfg, ccfg)?;
    print_row("coordinator", &coord_rep);

    let cs = coord.coordinator_stats();
    println!(
        "\ncoordinator: {} initial placements, {} prewarmed, {} rebalance ticks, \
         {} migrations, {} retirements ({} deferred)",
        cs.initial_placements,
        cs.prewarmed,
        cs.rebalance_ticks,
        cs.migrations,
        cs.retirements,
        cs.deferred_retirements
    );
    for ev in coord.migration_log() {
        println!(
            "  migrated adapter {} from server {} to server {}",
            ev.adapter, ev.from, ev.to
        );
    }
    let (sa, ca) = (
        static_rep.slo_attainment.unwrap_or(1.0),
        coord_rep.slo_attainment.unwrap_or(1.0),
    );
    println!(
        "coordinator {:.1}% vs static {:.1}% SLO attainment ({})",
        ca * 100.0,
        sa * 100.0,
        if ca >= sa {
            "coordinator ≥ static ✓"
        } else {
            "coordinator fell behind"
        }
    );
    Ok(())
}

fn cmd_chaos(args: &Args) -> anyhow::Result<()> {
    use caraserve::server::cluster::synthetic::{self, ChaosConfig, SyntheticConfig};
    use caraserve::server::{ColdStartMode, RetryPolicy};
    use caraserve::testkit::faults::FaultPlan;

    let smoke = args.flag("smoke");
    let mode = match args.opt_or("mode", "caraserve").as_str() {
        "cached" => ColdStartMode::Cached,
        "ondemand" | "ondmd" => ColdStartMode::OnDemand,
        _ => ColdStartMode::CaraServe,
    };
    let cfg = SyntheticConfig {
        instances: args
            .opt_parse_or("instances", if smoke { 2 } else { 3 })
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        requests: args
            .opt_parse_or("requests", if smoke { 12 } else { 32 })
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        adapters: args
            .opt_parse_or("adapters", 12)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        seed: args.opt_parse_or("seed", 1).map_err(|e| anyhow::anyhow!("{e}"))?,
        threads: args
            .opt_parse_or("threads", 1)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        // Chaos runs compare streams, not latency: keep the data plane
        // lean by default.
        cpu_workers: args
            .opt_parse_or("cpu-workers", 0)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        cold_start: mode,
        kv_pages: match args
            .opt_parse("pool-pages")
            .map_err(|e| anyhow::anyhow!("{e}"))?
        {
            Some(pages) => pages,
            None => args
                .opt_parse_or("kv-pages", 256)
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        },
        polls_per_arrival: args
            .opt_parse_or("pace", 2)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        skew: args
            .opt_parse_or("skew", 0.0)
            .map_err(|e| anyhow::anyhow!("{e}"))?,
    };
    // `--fault [server:]plan` — a leading all-digit field is the victim
    // backend index (the fault syntax itself uses `:` for counts, so
    // only a *numeric* first field can be a server prefix).
    let (victim, plan) = match args.opt("fault") {
        Some(spec) => match spec.split_once(':') {
            Some((pre, rest)) if !pre.is_empty() && pre.chars().all(|c| c.is_ascii_digit()) => {
                (pre.parse::<usize>()?, FaultPlan::parse(rest).map_err(|e| anyhow::anyhow!(e))?)
            }
            _ => (0, FaultPlan::parse(&spec).map_err(|e| anyhow::anyhow!(e))?),
        },
        // The canonical drill: kill server 0 at a seeded decode step.
        None => (0, FaultPlan::seeded_mid_decode_kill(cfg.seed, 2, 10)),
    };
    let retry = args
        .opt_parse("retries")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .map(|max_reroutes| RetryPolicy {
            max_reroutes,
            ..Default::default()
        });
    let chaos = ChaosConfig {
        faults: vec![(victim, plan.clone())],
        retry,
    };
    let policy = args.opt_or("policy", "rank-aware");

    println!(
        "chaos: {} native engines, {} requests, {} adapters, mode {mode:?}, \
         policy {policy}, seed {}",
        cfg.instances, cfg.requests, cfg.adapters, cfg.seed
    );
    println!("fault: server {victim} ← {plan}");
    let (rep, oracle) = synthetic::run_chaos(&policy, &cfg, &chaos)?;
    println!(
        "oracle: {} finished, {} rejected (no faults)",
        oracle.finished, oracle.rejected
    );
    println!(
        "chaos:  {} finished, {} rejected — {} bitwise-stable, {} diverged, \
         {} failed by fault",
        rep.base.finished, rep.base.rejected, rep.stable, rep.diverged, rep.failed
    );
    println!(
        "failover: {} re-placements, {} shed, final health {:?}",
        rep.failovers, rep.shed, rep.health
    );
    anyhow::ensure!(
        rep.diverged == 0,
        "{} stream(s) diverged from the no-fault oracle — failover is not bitwise-stable",
        rep.diverged
    );
    println!("every completed stream is bitwise-identical to the no-fault oracle ✓");
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let mode = match args.opt_or("mode", "caraserve").as_str() {
        "cached" => ServingMode::Cached,
        "ondmd" | "ondemand" => ServingMode::OnDemand,
        "s-lora" | "slora" => ServingMode::SLora,
        _ => ServingMode::CaraServe,
    };
    let rps: f64 = args.opt_parse_or("rps", 9.0).map_err(|e| anyhow::anyhow!("{e}"))?;
    let rank: usize = args.opt_parse_or("rank", 64).map_err(|e| anyhow::anyhow!("{e}"))?;
    let secs: f64 = args.opt_parse_or("secs", 300.0).map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed: u64 = args.opt_parse_or("seed", 1).map_err(|e| anyhow::anyhow!("{e}"))?;

    let reqs = caraserve::sim::workload::synthetic(seed, rps, rank, secs);
    let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    // 32 host cores for CPU LoRA (the paper's testbeds have 128+ vCPUs).
    let mut sim = Simulation::new(vec![SimInstance::new(0, model, mode, 64, 32, 512)]);
    let out = sim.run(&reqs, &mut SingleServer);

    println!(
        "mode={} requests={} rps={rps} rank={rank}",
        mode.name(),
        out.requests.len()
    );
    for metric in ["ttft", "tpt", "latency", "cold_frac"] {
        let col = out.column(metric);
        if let Some(s) = Summary::of(&col) {
            println!(
                "{metric:>10}: mean {:.2} ms  p50 {:.2} ms  p99 {:.2} ms",
                s.mean * 1e3,
                s.p50 * 1e3,
                s.p99 * 1e3
            );
        }
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> anyhow::Result<()> {
    let policy_name = args.opt_or("policy", "rank-aware");
    let n_instances: usize = args
        .opt_parse_or("instances", 8)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let kernel_name = args.opt_or("kernel", "bgmv");
    let rps: f64 = args.opt_parse_or("rps", 60.0).map_err(|e| anyhow::anyhow!("{e}"))?;
    let secs: f64 = args.opt_parse_or("secs", 60.0).map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed: u64 = args.opt_parse_or("seed", 1).map_err(|e| anyhow::anyhow!("{e}"))?;

    let kernel = KernelKind::parse(&kernel_name)
        .ok_or_else(|| anyhow::anyhow!("bad kernel {kernel_name}"))?;
    let gm = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);

    // Fit perf models by profiling the GPU model (what §5 does on real HW).
    let plan = profiler::ProfilePlan::default();
    let avg_ctx = 160usize;
    let dec_measure = |ranks: &[usize]| {
        gm.decode_iter(&vec![avg_ctx; ranks.len()]) + gm.lora_decode_overhead(kernel, ranks)
    };
    let pre_measure = |ranks: &[usize]| gm.prefill(ranks.len() * 28);
    let dec = profiler::calibrate(kernel, &plan, dec_measure).unwrap();
    let pre = profiler::calibrate(kernel, &plan, pre_measure).unwrap();
    let slo = 1.5 * gm.decode_iter(&[avg_ctx]);

    let mode = match kernel {
        KernelKind::Bgmv => ServingMode::CaraServe,
        KernelKind::Mbgmv => ServingMode::SLora,
    };
    let instances: Vec<SimInstance> = (0..n_instances)
        .map(|i| SimInstance::new(i, gm.clone(), mode, 64, 8, 512))
        .collect();
    let trace = MafTrace::new(seed, 2048, 1.0, &[8, 16, 32, 64]);
    let reqs = trace.generate(seed + 1, rps, secs);

    let mut policy = policy_by_name(
        &policy_name,
        pre,
        dec,
        RankAwareConfig {
            slo,
            ..Default::default()
        },
        seed,
    )?;
    let mut sim = Simulation::new(instances);
    let out = sim.run(&reqs, policy.as_mut());
    let tpt = out.column("tpt");
    println!(
        "policy={policy_name} kernel={kernel_name} instances={n_instances} requests={}",
        out.requests.len()
    );
    println!(
        "SLO ({:.1} ms): attainment {:.1}%  |  mean tpt {:.2} ms",
        slo * 1e3,
        out.slo_attainment(slo) * 100.0,
        mean(&tpt) * 1e3
    );
    Ok(())
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let kernel_name = args.opt_or("kernel", "bgmv");
    let kernel = KernelKind::parse(&kernel_name)
        .ok_or_else(|| anyhow::anyhow!("bad kernel {kernel_name}"))?;
    let gm = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let plan = profiler::ProfilePlan::default();
    let model: PerfModel = profiler::calibrate(kernel, &plan, |ranks| {
        gm.decode_iter(&vec![160; ranks.len()]) + gm.lora_decode_overhead(kernel, ranks)
    })
    .unwrap();
    println!(
        "kernel={kernel_name}: alpha={:.3e} s/feature, beta={:.2} ms, R^2={:.4}",
        model.alpha,
        model.beta * 1e3,
        model.r2
    );
    Ok(())
}

fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    let root = args.opt_or("root", ".");
    let report = caraserve::analysis::lint_tree(std::path::Path::new(&root))?;
    if let Some(path) = args.opt("json") {
        std::fs::write(path, report.to_json().to_string_pretty())?;
    }
    print!("{}", report.render_table());
    if !report.is_clean() {
        anyhow::bail!(
            "{} lint violation(s) — fix or allowlist in rust/lint-allow.txt",
            report.violations.len()
        );
    }
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    println!("Base models (paper Table 2):");
    println!(
        "{:<12} {:>8} {:>7} {:>9} {:>12}",
        "model", "hidden", "layers", "params", "gpu config"
    );
    for (cfg, gpus) in [
        (LlamaConfig::llama2_7b(), "1x A10"),
        (LlamaConfig::llama2_13b(), "2x A10"),
        (LlamaConfig::llama2_70b(), "4x A100"),
        (LlamaConfig::tiny(), "cpu-pjrt"),
    ] {
        println!(
            "{:<12} {:>8} {:>7} {:>8.1}B {:>12}",
            cfg.name,
            cfg.hidden,
            cfg.layers,
            cfg.param_count() / 1e9,
            gpus
        );
    }
    Ok(())
}
